//! Fig 9 — effect of model size: per-object prefill compute vs KV size
//! across the three configs (paper: LLaMA 3B/8B/70B), at 1,024 and 2,048
//! input tokens. Shape to reproduce: prefill compute grows faster with
//! model size than KV bytes do, so MatKV's benefit (prefill time /
//! load time) widens with model scale, at both input lengths.

use matkv::coordinator::{Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::{ArchSpec, DeviceProfile, StorageProfile};
use matkv::util::bench::Table;
use matkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.usize("requests", 6);
    let h100 = DeviceProfile::h100();
    let ssd = StorageProfile::raid0_4x9100();

    for (label, top_k) in [("1,024 input tokens (Fig 9a)", 1usize), ("2,048 input tokens (Fig 9b)", 2)] {
        let mut table = Table::new(
            &format!("Fig 9 — model-size sweep, {label}"),
            &["config", "role", "prefill/obj (sim ms)", "KV MB/obj", "load/obj (ms)", "benefit"],
        );
        for (name, role) in [("tiny", "3B-class"), ("small", "8B-class"), ("base", "70B-class")] {
            let sc = Scenario::build(ScenarioSpec {
                config: name.into(),
                storage: ssd.clone(),
                n_docs: 8,
                doc_tokens: 1024,
                seed: 14,
                ..ScenarioSpec::default()
            })?;
            let reqs = sc.requests(n, top_k, 4);
            let arch = ArchSpec::standin_for(name);
            let (_, v) = sc.engine.serve_all(&reqs, 1, ServeMode::Vanilla)?;
            let (_, m) = sc.engine.serve_all(&reqs, 1, ServeMode::MatKv)?;
            let objs = (n * top_k) as f64;
            let prefill_ms = v.prefill_secs_on(&arch, &h100) / objs * 1e3;
            let kv_mb = arch.kv_bytes(1024) / 1e6;
            let load_ms =
                (m.load_secs_on(&arch, &ssd) + m.upload_secs_on(&arch, &h100)) / objs * 1e3;
            table.row(&[
                format!("{name} ({})", arch.name),
                role.to_string(),
                format!("{prefill_ms:.3}"),
                format!("{kv_mb:.1}"),
                format!("{load_ms:.3}"),
                format!("{:.1}x", prefill_ms / load_ms),
            ]);
        }
        table.print();
    }
    println!("\npaper shape: compute/obj (blue) grows faster than KV size (green) with model scale,");
    println!("so the MatKV benefit (red) widens; consistent across input lengths.");
    Ok(())
}
