//! Scheduler-policy bench — FIFO vs tier-affinity continuous batching at
//! equal batch size over a Zipf-skewed Poisson arrival trace.
//!
//! The co-design claim: *which requests share a batch* determines how
//! many device reads the storage tier absorbs. Two phases:
//!
//! 1. **Load-path replay** (no artifacts needed): the same arrival trace
//!    is planned under each policy and every planned batch's retrieval
//!    top-K is demand-loaded through an identical tiered, sharded store.
//!    Tier-affinity batches group chunk-sharers (one `load_many` read
//!    per repeated id — splice reuse) and requests whose chunks are
//!    already resident, so at equal batch size it must show
//!    `cache_hits` ≥ FIFO with strictly fewer shard device reads.
//!    Emits the hot tier's telemetry series per policy.
//! 2. **Full engine** (needs `make artifacts`; skipped otherwise):
//!    `Scheduler::run` through the overlap pipeline with prefetch on,
//!    both policies, reporting serve-side `cache_hits`, per-shard reads
//!    and queue waits.
//!
//! `--smoke` shrinks everything for CI; `--json PATH` writes rows +
//! telemetry as JSON. Acceptance shape: in the JSON, the affinity row
//! has `cache_hits >= fifo` and `device_reads < fifo`.

use std::fmt::Write as _;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use matkv::coordinator::engine::{EngineOptions, LoaderCtx, Retrieval};
use matkv::coordinator::{
    BatchPolicy, ExecOptions, OverlapOptions, SchedOptions, SchedPolicy, Scenario, ScenarioSpec,
    Scheduler, ServeMode,
};
use matkv::hwsim::StorageProfile;
use matkv::kvstore::store::config_id;
use matkv::kvstore::{series_to_json, KvChunk, KvStore, TierMetrics};
use matkv::obs::{MetricsRegistry, Sampler};
use matkv::manifest::Manifest;
use matkv::util::bench::Table;
use matkv::util::cli::Args;
use matkv::util::tempdir::TempDir;
use matkv::vectordb::VectorIndex;
use matkv::workload::{ArrivalGen, Corpus, TimedRequest, TurboRagProfile};

/// A chunk whose dims match the config (so the store's accounting sees
/// realistic sizes); payload content is irrelevant to scheduling.
fn cfg_chunk(cfg: &matkv::ModelConfig, seq: usize) -> KvChunk {
    let plane = cfg.n_layers * cfg.n_kv_heads * seq * cfg.head_dim;
    KvChunk {
        config_id: config_id(cfg),
        n_layers: cfg.n_layers as u32,
        n_kv_heads: cfg.n_kv_heads as u32,
        seq_len: seq as u32,
        head_dim: cfg.head_dim as u32,
        k: vec![1.0; plane],
        v: vec![-1.0; plane],
    }
}

struct PolicyRow {
    name: &'static str,
    loads: usize,
    cache_hits: u64,
    device_reads: u64,
    device_secs: f64,
    shard_reads: Vec<u64>,
    batches: usize,
    mean_wait_ms: f64,
    max_wait_ms: f64,
    forced: usize,
    series_json: String,
    metrics_json: String,
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let smoke = args.flag("smoke");
    let n_docs = args.usize("docs", if smoke { 24 } else { 64 });
    let doc_tokens = 256usize;
    let requests = args.usize("requests", if smoke { 64 } else { 256 });
    let batch = args.usize("batch", 8);
    let shards = args.usize("shards", if smoke { 2 } else { 4 });
    let skew = args.f64("skew", 1.1);
    // Slightly overloaded by default (capacity = batch/service = 320/s
    // vs 400/s offered): a persistent backlog is what gives the policy a
    // pool to choose from, exactly like continuous batching under load.
    let rate = args.f64("arrival-rate", 400.0);
    let service_ms = args.f64("service-ms", 25.0);
    let max_age = args.usize("max-age-batches", if smoke { 8 } else { 16 });
    let top_k = 2usize;

    let m = Manifest::load_or_golden()?;
    let cfg = m.config("tiny")?.clone();
    let opts = EngineOptions::for_config(&m, "tiny")?;
    let corpus = Corpus::generate(n_docs, 64, n_docs, 42);

    // Retrieval stack without a PJRT session — the shared constructor
    // Engine::new uses, so the bench models the engine's exact
    // retrieval distribution.
    let retrieval =
        Arc::new(Retrieval::for_corpus(corpus.texts(), cfg.vocab as u32, opts.embed_dim));
    {
        let mut ix = retrieval.index.write().unwrap();
        for d in &corpus.docs {
            let (ids, _) = retrieval.tokenizer.encode_block(&d.text, doc_tokens);
            ix.insert(d.id, retrieval.embedder.embed(&ids));
        }
    }

    // Same trace for every policy: Zipf-skewed topics, Poisson arrivals.
    let trace: Vec<TimedRequest> = ArrivalGen::new(
        TurboRagProfile { top_k, query_tokens: 20.0, output_tokens: 8 },
        corpus.n_topics,
        skew,
        rate,
        7,
    )
    .take(&corpus, requests);

    let tier_budget = cfg_chunk(&cfg, doc_tokens).dram_bytes() * n_docs / 4; // 25% of corpus
    eprintln!(
        "[fig_sched] {requests} reqs @ {rate}/s Zipf({skew}) over {n_docs} docs, batch {batch}, \
         {shards} shards, 25% tier, service {service_ms}ms"
    );

    // ---- phase 1: load-path replay of the planned schedules ------------
    let mut rows: Vec<PolicyRow> = Vec::new();
    for (name, policy) in [
        ("fifo", SchedPolicy::Fifo),
        ("affinity", SchedPolicy::TierAffinity { max_age_batches: max_age }),
    ] {
        let dir = TempDir::new("matkv-fig-sched")?;
        let mut kv =
            KvStore::open_sharded(dir.path(), StorageProfile::ssd_9100pro(), shards)?;
        kv.disable_throttle(); // simulated device seconds still computed
        for d in &corpus.docs {
            kv.store_sync(d.id, &cfg_chunk(&cfg, doc_tokens))?;
        }
        kv.set_hot_tier(tier_budget);
        let ctx = LoaderCtx {
            retrieval: retrieval.clone(),
            kv: Arc::new(kv),
            cfg: cfg.clone(),
            opts: opts.clone(),
        };
        let mut sched = Scheduler::new(
            ctx.clone(),
            SchedOptions {
                batch: BatchPolicy { max_batch: batch, max_wait_secs: 0.05 },
                policy,
                service_estimate_secs: service_ms / 1e3,
                estimator: None,
            },
        );
        // Per-policy registry over the whole storage hierarchy, with the
        // planner driving the sampler on its virtual release clock.
        let reg = MetricsRegistry::new();
        ctx.kv.register_metrics(&reg)?;
        let sampler = Arc::new(std::sync::Mutex::new(Sampler::new(reg.clone(), 0.05)));
        sched.set_metrics(&reg, Some(sampler.clone()))?;
        sched.enqueue_timed(trace.clone());
        let plan = sched.plan_with_retrieval();
        sampler.lock().unwrap().finish(plan.report.makespan_secs);

        let mut loads = 0usize;
        let mut cache_hits = 0u64;
        let mut device_secs = 0.0;
        for b in &plan.batches {
            let ids = b.chunk_ids();
            loads += ids.len();
            for l in ctx.kv.load_many(&ids)? {
                cache_hits += l.from_cache as u64;
                device_secs += l.device_secs;
            }
            if let Some(tier) = ctx.kv.hot_tier() {
                tier.sample();
            }
        }
        let shard_reads: Vec<u64> = ctx
            .kv
            .shards()
            .iter()
            .map(|s| s.stats.reads.load(Ordering::Relaxed))
            .collect();
        rows.push(PolicyRow {
            name,
            loads,
            cache_hits,
            device_reads: ctx.kv.stats.reads.load(Ordering::Relaxed),
            device_secs,
            shard_reads,
            batches: plan.report.batches,
            mean_wait_ms: plan.report.mean_wait_secs * 1e3,
            max_wait_ms: plan.report.max_wait_secs * 1e3,
            forced: plan.report.forced_includes,
            series_json: ctx
                .kv
                .hot_tier()
                .map(|t| series_to_json(&t.stats.series()))
                .unwrap_or_else(|| "[]".into()),
            metrics_json: sampler.lock().unwrap().to_json(),
        });
    }

    let mut table = Table::new(
        &format!(
            "batch formation vs storage tier ({requests} reqs, batch {batch}, {shards} shards)"
        ),
        &[
            "policy",
            "batches",
            "loads",
            "cache hits",
            "device reads",
            "device secs",
            "wait mean/max (ms)",
            "forced",
        ],
    );
    for r in &rows {
        table.row(&[
            r.name.to_string(),
            r.batches.to_string(),
            r.loads.to_string(),
            r.cache_hits.to_string(),
            r.device_reads.to_string(),
            format!("{:.3}", r.device_secs),
            format!("{:.1}/{:.1}", r.mean_wait_ms, r.max_wait_ms),
            r.forced.to_string(),
        ]);
    }
    table.print();
    let (fifo, aff) = (&rows[0], &rows[1]);
    println!(
        "\naffinity vs fifo at equal batch size: cache hits {} -> {} ({:+}), device reads \
         {} -> {} ({:+})",
        fifo.cache_hits,
        aff.cache_hits,
        aff.cache_hits as i64 - fifo.cache_hits as i64,
        fifo.device_reads,
        aff.device_reads,
        aff.device_reads as i64 - fifo.device_reads as i64,
    );
    if aff.device_reads >= fifo.device_reads {
        eprintln!(
            "[fig_sched] WARNING: affinity did not reduce device reads \
             (affinity {} vs fifo {})",
            aff.device_reads, fifo.device_reads
        );
    }

    // ---- phase 2: full engine through the overlap pipeline -------------
    let mut engine_json = String::from("null");
    if matkv::manifest::artifacts_present() {
        let mut parts = Vec::new();
        for (name, policy) in [
            ("fifo", SchedPolicy::Fifo),
            ("affinity", SchedPolicy::TierAffinity { max_age_batches: max_age }),
        ] {
            let sc = Scenario::build(ScenarioSpec {
                n_docs: if smoke { 8 } else { 16 },
                doc_tokens: 256,
                storage: StorageProfile::ssd_9100pro(),
                hot_tier_bytes: tier_budget,
                shards: shards.min(4),
                seed: 21,
                ..ScenarioSpec::default()
            })?;
            let trace = ArrivalGen::new(
                TurboRagProfile { top_k: 2, query_tokens: 20.0, output_tokens: 4 },
                sc.corpus.n_topics,
                skew,
                rate,
                7,
            )
            .take(&sc.corpus, if smoke { 16 } else { 48 });
            let mut sched = Scheduler::new(
                sc.engine.loader_ctx(),
                SchedOptions {
                    batch: BatchPolicy { max_batch: 4, max_wait_secs: 0.05 },
                    policy,
                    service_estimate_secs: service_ms / 1e3,
                    estimator: None,
                },
            );
            sched.enqueue_timed(trace);
            let out = sched.run(
                &sc.engine,
                ServeMode::MatKv,
                &ExecOptions::overlapped(OverlapOptions { prefetch: true, lookahead: 2 }),
            )?;
            println!(
                "engine ({name:8}): {} responses, cache_hits {}, device reads {}, \
                 stalls {:.4}s, prefetch warmed {}",
                out.responses.len(),
                out.metrics.cache_hits,
                out.metrics.load_reads,
                out.overlap.exec_stall_secs,
                out.overlap.prefetch_warmed,
            );
            parts.push(format!(
                "{{\"policy\":\"{name}\",\"cache_hits\":{},\"device_reads\":{},\
                 \"exec_stall_secs\":{:.6},\"prefetch_warmed\":{},\"batches\":{}}}",
                out.metrics.cache_hits,
                out.metrics.load_reads,
                out.overlap.exec_stall_secs,
                out.overlap.prefetch_warmed,
                out.sched.batches,
            ));
        }
        engine_json = format!("[{}]", parts.join(","));
    } else {
        println!(
            "\n[fig_sched] engine phase skipped: AOT artifacts not built (run `make artifacts`)"
        );
    }

    if let Some(path) = args.opt("json") {
        let mut policy_rows = String::new();
        for r in &rows {
            let shard_reads: Vec<String> =
                r.shard_reads.iter().map(u64::to_string).collect();
            let _ = write!(
                policy_rows,
                "{}{{\"policy\":\"{}\",\"batches\":{},\"loads\":{},\"cache_hits\":{},\
                 \"device_reads\":{},\"device_secs\":{:.6},\"shard_reads\":[{}],\
                 \"mean_wait_ms\":{:.3},\"max_wait_ms\":{:.3},\"forced_includes\":{},\
                 \"series\":{},\"metrics\":{}}}",
                if policy_rows.is_empty() { "" } else { "," },
                r.name,
                r.batches,
                r.loads,
                r.cache_hits,
                r.device_reads,
                r.device_secs,
                shard_reads.join(","),
                r.mean_wait_ms,
                r.max_wait_ms,
                r.forced,
                r.series_json,
                r.metrics_json,
            );
        }
        let doc = format!(
            "{{\"bench\":\"fig_sched\",\"smoke\":{smoke},\"requests\":{requests},\
             \"batch\":{batch},\"docs\":{n_docs},\"shards\":{shards},\"skew\":{skew},\
             \"arrival_rate\":{rate},\"service_ms\":{service_ms},\
             \"policies\":[{policy_rows}],\
             \"affinity_hit_gain\":{},\"affinity_read_saving\":{},\"engine\":{engine_json}}}",
            aff.cache_hits as i64 - fifo.cache_hits as i64,
            fifo.device_reads as i64 - aff.device_reads as i64,
        );
        std::fs::write(path, doc)?;
        eprintln!("[fig_sched] wrote {path}");
    }
    Ok(())
}
