//! Fig 2 — distribution of accessed vectors in RAG retrieval.
//!
//! Paper: 1M top-10 queries against a 9M-chunk deep1B vector database;
//! >900K chunks (~10%) accessed twice or more. Scaled reproduction:
//! 100K chunks in the IVF index, 20K top-10 Zipf-skewed queries; we
//! report the access-frequency histogram and the repeat mass. Shape to
//! reproduce: heavy skew — a large fraction of accessed chunks repeat,
//! which is exactly the population the ten-day rule targets.

use std::collections::HashMap;

use matkv::util::bench::Table;
use matkv::util::cli::Args;
use matkv::vectordb::{IvfIndex, VectorIndex};
use matkv::workload::{Rng, Zipf};

fn unit_vec(dim: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v: Vec<f32> = (0..dim).map(|_| rng.f64() as f32 - 0.5).collect();
    matkv::vectordb::embed::l2_normalize(&mut v);
    v
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n_chunks = args.usize("chunks", 100_000);
    let n_queries = args.usize("queries", 20_000);
    let dim = 64;

    eprintln!("[fig2] building IVF index over {n_chunks} chunks ...");
    let sample: Vec<Vec<f32>> = (0..512).map(|i| unit_vec(dim, i as u64)).collect();
    let mut ix = IvfIndex::new(dim, 128, 4, 77);
    ix.train(&sample, 4);
    for i in 0..n_chunks {
        ix.insert(i as u64, unit_vec(dim, i as u64));
    }

    // Queries: Zipf-skewed over "intents"; each intent perturbs the
    // embedding of a popular chunk (real queries cluster around topics).
    eprintln!("[fig2] running {n_queries} top-10 queries ...");
    let zipf = Zipf::new(n_chunks, 0.9);
    let mut rng = Rng::new(3);
    let mut counts: HashMap<u64, u32> = HashMap::new();
    for _ in 0..n_queries {
        let intent = zipf.sample(&mut rng) as u64;
        let mut q = unit_vec(dim, intent);
        // small perturbation so top-10 isn't a constant set
        for x in q.iter_mut() {
            *x += (rng.f64() as f32 - 0.5) * 0.05;
        }
        matkv::vectordb::embed::l2_normalize(&mut q);
        for hit in ix.search(&q, 10) {
            *counts.entry(hit.chunk_id).or_default() += 1;
        }
    }

    let accessed = counts.len();
    let mut table = Table::new(
        &format!("Fig 2 — access frequency ({n_queries} top-10 queries over {n_chunks} chunks)"),
        &["accessed >= k times", "chunks", "% of corpus"],
    );
    for k in [1u32, 2, 5, 10, 100] {
        let c = counts.values().filter(|&&v| v >= k).count();
        table.row(&[
            format!(">= {k}"),
            c.to_string(),
            format!("{:.1}%", 100.0 * c as f64 / n_chunks as f64),
        ]);
    }
    table.print();

    let repeated = counts.values().filter(|&&v| v >= 2).count();
    println!(
        "\n{} distinct chunks accessed; {} ({:.1}% of corpus) accessed 2+ times.",
        accessed,
        repeated,
        100.0 * repeated as f64 / n_chunks as f64
    );
    println!("paper shape: ~10% of the whole corpus accessed twice or more (skewed reuse).");
    Ok(())
}
