//! fig_fault — fault injection & graceful degradation: the same
//! Poisson×Zipf trace served clean and under a deterministic fault
//! plan, with zero failed requests either way.
//!
//! The robustness claim this bench pins: MatKV's serving stack never
//! *fails* a request when the storage or the fleet degrades — it
//! degrades. The ladder (PR 7):
//!
//! * flash reads verify a per-chunk **v3 checksum**; corrupted payloads
//!   are rejected and retried with exponential backoff charged on the
//!   shard's link clock;
//! * reads that stay dead after `max_retries` re-probe the DRAM tiers,
//!   then fall back to **Vanilla recompute** of just the lost chunks;
//! * a crashed fleet worker's in-flight batches are **requeued** onto
//!   the survivors with their arrival times preserved, and role-aware
//!   routing rebalances around the dead card;
//! * chunks on a dead shard price as on-device recompute at the
//!   assigned worker's roofline rate.
//!
//! Two halves, both pure-rust on the virtual clock (no PJRT):
//!
//! 1. **Store ladder** — a sharded store under a plan that kills shard
//!    0 and corrupts shard 1's first read: every `load_many` still
//!    returns real KV bytes, with nonzero retry/checksum/recompute
//!    telemetry.
//! 2. **Fleet failover** — one planned schedule dispatched three times
//!    through a 1×H100+3×RTX4090 fleet: twice clean (the runs must be
//!    bit-identical — the fault plumbing is provably inert when off)
//!    and once faulted (dead shard + decode-worker crash). Every
//!    request completes; the p99/goodput gap is reported and warned on
//!    if unbounded.
//!
//! `--smoke` shrinks everything; `--json PATH` writes the document CI
//! asserts on (`failed_requests == 0`, `recomputed_chunks > 0`).

use std::sync::Arc;

use matkv::coordinator::engine::{EngineOptions, LoaderCtx, Retrieval};
use matkv::coordinator::{
    BatchPolicy, Fleet, FleetCostModel, FleetSpec, Routing, SchedOptions, SchedPolicy, Scheduler,
};
use matkv::hwsim::{ArchSpec, FaultPlan, StorageProfile};
use matkv::kvstore::{KvChunk, KvStore};
use matkv::manifest::Manifest;
use matkv::util::bench::Table;
use matkv::util::cli::Args;
use matkv::util::tempdir::TempDir;
use matkv::workload::{ArrivalGen, Corpus, TimedRequest, TurboRagProfile};

/// A tiny synthetic chunk (integer payloads survive f16 exactly).
fn chunk(seed: u32, seq: u32) -> KvChunk {
    let plane = (2 * 2 * seq * 4) as usize;
    KvChunk {
        config_id: 0xabcd,
        n_layers: 2,
        n_kv_heads: 2,
        seq_len: seq,
        head_dim: 4,
        k: (0..plane).map(|i| (i as f32) + seed as f32).collect(),
        v: (0..plane).map(|i| -(i as f32) - seed as f32).collect(),
    }
}

/// Aggregated store-ladder telemetry.
#[derive(Default)]
struct StoreRecovery {
    loads: usize,
    retries: usize,
    backoff_secs: f64,
    checksum_failures: usize,
    recomputed: usize,
    recompute_secs: f64,
    degraded_tokens: usize,
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let smoke = args.flag("smoke");
    let n_docs = args.usize("docs", if smoke { 24 } else { 48 });
    let requests = args.usize("requests", if smoke { 32 } else { 96 });
    let batch = args.usize("batch", 4);
    let skew = args.f64("skew", 1.1);
    let rate = args.f64("arrival-rate", 300.0);
    let chunk_tokens = 256usize;
    let top_k = 2usize;
    let output_tokens = 4usize;
    let fleet_spec = "h100:1,rtx4090:3";
    // One decode card dies mid-trace; flash shard 0 is dead on arrival.
    let fault_spec = "seed=7,shard0:die@0,worker3:crash@0.05";

    // ---- store half: the degradation ladder under injected faults ----
    // Shard 0 dead from read 0 (→ recompute fallback), shard 1's first
    // read silently corrupted (→ checksum catch + retry).
    let store_dir = TempDir::new("matkv-fig-fault-store")?;
    let mut kv = KvStore::open_sharded(store_dir.path(), StorageProfile::ssd_9100pro(), 2)?;
    kv.disable_throttle();
    let n_chunks = 12u64;
    for id in 0..n_chunks {
        kv.store_sync(id, &chunk(id as u32, 64))?;
    }
    let store_plan = Arc::new(FaultPlan::parse("seed=7,shard0:die@0,shard1:corrupt@0")?);
    kv.set_faults(Some(store_plan.clone()));
    kv.set_retry_policy(2, 0.001);
    kv.set_recompute_model(5e-5);
    let ids: Vec<u64> = (0..n_chunks).collect();
    let loaded = kv.load_many(&ids)?; // must succeed despite the plan
    let mut sr = StoreRecovery { loads: loaded.len(), ..Default::default() };
    for l in &loaded {
        sr.retries += l.retries;
        sr.backoff_secs += l.retry_backoff_secs;
        sr.checksum_failures += l.checksum_failures;
        if l.recomputed {
            sr.recomputed += 1;
            sr.recompute_secs += l.recompute_secs;
            sr.degraded_tokens += l.chunk.seq_len as usize;
        }
    }
    // Degraded or not, every load must serve the real KV bytes.
    for (i, l) in loaded.iter().enumerate() {
        let want = chunk(i as u32, 64);
        anyhow::ensure!(
            l.chunk.k == want.k && l.chunk.v == want.v,
            "chunk {i} served wrong bytes under faults"
        );
    }
    eprintln!(
        "[fig_fault] store ladder: {} loads, {} retries ({:.4}s backoff), {} checksum \
         failures, {} recomputed ({} degraded tokens) — zero failed loads",
        sr.loads, sr.retries, sr.backoff_secs, sr.checksum_failures, sr.recomputed,
        sr.degraded_tokens,
    );
    if sr.recomputed == 0 || sr.checksum_failures == 0 {
        eprintln!(
            "[fig_fault] WARNING: the store plan drew no recompute/checksum events \
             (recomputed {}, checksum {}) — the ladder was not exercised",
            sr.recomputed, sr.checksum_failures
        );
    }

    // ---- fleet half: clean ×2 (bit-identity) vs faulted dispatch -----
    let m = Manifest::load_or_golden()?;
    let cfg = m.config("tiny")?.clone();
    let corpus = Corpus::generate(n_docs, 64, n_docs, 42);
    let retrieval = {
        let opts = EngineOptions::for_config(&m, "tiny")?;
        Arc::new(Retrieval::for_corpus(corpus.texts(), cfg.vocab as u32, opts.embed_dim))
    };
    {
        let mut ix = retrieval.index.write().unwrap();
        for d in &corpus.docs {
            let (ids, _) = retrieval.tokenizer.encode_block(&d.text, chunk_tokens);
            ix.insert(d.id, retrieval.embedder.embed(&ids));
        }
    }
    let dir = TempDir::new("matkv-fig-fault")?;
    let mut fleet_kv = KvStore::open_sharded(dir.path(), StorageProfile::ssd_9100pro(), 2)?;
    fleet_kv.disable_throttle();
    let fleet_kv = Arc::new(fleet_kv);

    let model = FleetCostModel {
        arch: ArchSpec::llama_70b(),
        storage: StorageProfile::ssd_9100pro(),
        chunk_tokens,
        query_tokens: 20,
        chunk_step: 256,
    };
    let spec = FleetSpec::parse(fleet_spec)?;
    let estimator = Fleet::new(&spec, Routing::RoleAware, model.clone()).service_estimator();

    let trace: Vec<TimedRequest> = ArrivalGen::new(
        TurboRagProfile { top_k, query_tokens: 20.0, output_tokens },
        corpus.n_topics,
        skew,
        rate,
        7,
    )
    .take(&corpus, requests);
    let ctx = LoaderCtx {
        retrieval: retrieval.clone(),
        kv: fleet_kv.clone(),
        cfg: cfg.clone(),
        opts: EngineOptions::for_config(&m, "tiny")?,
    };
    let mut sched = Scheduler::new(
        ctx,
        SchedOptions {
            batch: BatchPolicy { max_batch: batch, max_wait_secs: 0.05 },
            policy: SchedPolicy::Fifo,
            service_estimate_secs: 0.0,
            estimator: Some(estimator.clone()),
        },
    );
    sched.enqueue_timed(trace);
    let plan = sched.plan_with_retrieval();

    eprintln!(
        "[fig_fault] {requests} reqs Zipf({skew}) @ {rate}/s over {n_docs} docs, \
         {} batches, fleet {fleet_spec}, plan {fault_spec:?}",
        plan.batches.len()
    );

    // Clean dispatch, twice: with no plan installed the fault plumbing
    // must be provably inert — the PR-6 dispatch, bit for bit.
    let clean_run = || {
        let mut fleet = Fleet::new(&spec, Routing::RoleAware, model.clone());
        fleet.dispatch(&plan.batches, &|_| true)
    };
    let clean = clean_run();
    let clean2 = clean_run();
    if clean.assignments != clean2.assignments
        || clean.makespan_secs != clean2.makespan_secs
        || clean.latency != clean2.latency
    {
        eprintln!(
            "[fig_fault] WARNING: two clean dispatches of the same plan diverged — \
             the fault-off path is not bit-identical"
        );
    }
    if clean.metrics.requeued_requests != 0 || clean.metrics.recomputed_chunks != 0 {
        eprintln!("[fig_fault] WARNING: clean run reports nonzero recovery counters");
    }

    // Faulted dispatch: dead shard 0 (lost chunks recompute at the
    // assigned worker) + decode worker 3 crashing mid-trace (in-flight
    // batches requeue onto the survivors).
    let fleet_plan = Arc::new(FaultPlan::parse(fault_spec)?);
    let faulted = {
        let mut fleet = Fleet::new(&spec, Routing::RoleAware, model.clone());
        fleet.set_faults(fleet_plan.clone());
        let (kv, p) = (fleet_kv.clone(), fleet_plan.clone());
        fleet.set_lost_chunks(Arc::new(move |id| p.shard_dead(kv.shard_index_of(id))));
        fleet.dispatch(&plan.batches, &|_| true)
    };

    let failed_requests = requests.saturating_sub(faulted.requests);
    let p99_gap_ms = (faulted.latency.p99 - clean.latency.p99) * 1e3;
    let goodput_gap = clean.throughput() - faulted.throughput();

    let mut table = Table::new(
        &format!(
            "fault injection A/B — {fleet_spec}, role-aware ({requests} reqs, batch {batch}, \
             virtual clock)"
        ),
        &["run", "requests", "tok/s", "p99 (ms)", "requeued", "recomputed", "degraded tok"],
    );
    for (name, rep) in [("clean", &clean), ("faulted", &faulted)] {
        table.row(&[
            name.to_string(),
            rep.requests.to_string(),
            format!("{:.1}", rep.throughput()),
            format!("{:.0}", rep.latency.p99 * 1e3),
            rep.metrics.requeued_requests.to_string(),
            rep.metrics.recomputed_chunks.to_string(),
            rep.metrics.degraded_tokens.to_string(),
        ]);
    }
    table.print();
    println!(
        "\nfaults cost {:+.1} tok/s and {:+.0}ms p99; {} requests requeued off the dead \
         card, {} chunks recomputed off the dead shard — {} failed requests",
        goodput_gap,
        p99_gap_ms,
        faulted.metrics.requeued_requests,
        faulted.metrics.recomputed_chunks,
        failed_requests,
    );

    if failed_requests > 0 {
        eprintln!(
            "[fig_fault] WARNING: {failed_requests} requests never completed under faults — \
             graceful degradation is broken"
        );
    }
    if faulted.metrics.recomputed_chunks == 0 {
        eprintln!(
            "[fig_fault] WARNING: no chunks recomputed despite a dead shard — the \
             lost-chunk predicate is not reaching dispatch"
        );
    }
    if faulted.metrics.requeued_requests == 0 {
        eprintln!(
            "[fig_fault] WARNING: no requests requeued despite a worker crash at t=0.05 — \
             the crash never interrupted in-flight work (check the trace length)"
        );
    }
    // Bounded degradation: the faulted tail may stretch, but not
    // explode — an unbounded gap means requeues are thrashing.
    if clean.latency.p99 > 0.0 && faulted.latency.p99 > 20.0 * clean.latency.p99 {
        eprintln!(
            "[fig_fault] WARNING: faulted p99 {:.0}ms is more than 20x the clean {:.0}ms — \
             degradation is not bounded",
            faulted.latency.p99 * 1e3,
            clean.latency.p99 * 1e3
        );
    }

    if let Some(path) = args.opt("json") {
        let recomputed_total = sr.recomputed + faulted.metrics.recomputed_chunks;
        let doc = format!(
            "{{\"bench\":\"fig_fault\",\"smoke\":{smoke},\"requests\":{requests},\
             \"batch\":{batch},\"docs\":{n_docs},\"skew\":{skew},\"arrival_rate\":{rate},\
             \"fleet\":\"{fleet_spec}\",\"fault_plan\":\"{fault_spec}\",\
             \"failed_requests\":{failed_requests},\"recomputed_chunks\":{recomputed_total},\
             \"store\":{{\"loads\":{},\"retries\":{},\"backoff_secs\":{:.6},\
             \"checksum_failures\":{},\"recomputed\":{},\"recompute_secs\":{:.6},\
             \"degraded_tokens\":{}}},\
             \"requeued_requests\":{},\"p99_gap_ms\":{:.3},\"goodput_gap\":{:.3},\
             \"clean_bit_identical\":{},\"clean\":{},\"faulted\":{}}}",
            sr.loads,
            sr.retries,
            sr.backoff_secs,
            sr.checksum_failures,
            sr.recomputed,
            sr.recompute_secs,
            sr.degraded_tokens,
            faulted.metrics.requeued_requests,
            p99_gap_ms,
            goodput_gap,
            clean.assignments == clean2.assignments && clean.latency == clean2.latency,
            clean.to_json(),
            faulted.to_json(),
        );
        std::fs::write(path, doc)?;
        eprintln!("[fig_fault] wrote {path}");
    }
    Ok(())
}
