//! Hot-tier hit-ratio curve — hot-tier size vs Zipf skew.
//!
//! Fig 2 established that RAG retrieval traffic is heavily skewed; the
//! ten-day rule says the repeated mass is exactly what materialization
//! pays for. This bench closes the loop for the new storage hierarchy:
//! sweep the DRAM hot tier's byte budget (as a % of the corpus KV
//! footprint) against the Zipf skew of the access stream and report the
//! hit ratio and simulated device-read seconds. Shape to reproduce:
//! near-zero hits at s=0 (uniform — the tier only holds its capacity
//! share), and a hit ratio far above the capacity share at s>=1, where
//! a top-decile tier absorbs roughly half the accesses.

use std::fmt::Write as _;

use matkv::hwsim::StorageProfile;
use matkv::kvstore::{series_to_json, KvChunk, KvStore, TierMetrics};
use matkv::obs::{register_tier, MetricsRegistry, Sampler};
use matkv::util::bench::Table;
use matkv::util::cli::Args;
use matkv::util::tempdir::TempDir;
use matkv::workload::{Rng, Zipf};

fn chunk(seed: u32, seq: u32) -> KvChunk {
    let plane = (2 * 2 * seq * 8) as usize;
    KvChunk {
        config_id: 0x7157,
        n_layers: 2,
        n_kv_heads: 2,
        seq_len: seq,
        head_dim: 8,
        k: (0..plane).map(|i| ((i + seed as usize) % 1024) as f32).collect(),
        v: (0..plane).map(|i| -(((i + seed as usize) % 1024) as f32)).collect(),
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let smoke = args.flag("smoke");
    let n_chunks = args.usize("chunks", if smoke { 64 } else { 256 });
    let accesses = args.usize("accesses", if smoke { 800 } else { 4000 });
    let seq = args.usize("chunk-tokens", 128) as u32;

    // Materialize the corpus once; every (skew, budget) cell reopens the
    // same directory with a fresh tier so stats start clean.
    let dir = TempDir::new("matkv-fig-tier")?;
    {
        let mut w = KvStore::open(dir.path(), StorageProfile::ssd_9100pro())?;
        w.disable_throttle();
        for i in 0..n_chunks {
            w.store_sync(i as u64, &chunk(i as u32, seq))?;
        }
    }
    let per_chunk = chunk(0, seq).dram_bytes();
    eprintln!(
        "[fig_tier_hit] {n_chunks} chunks x {seq} tokens ({:.1} MB corpus), {accesses} accesses",
        (per_chunk * n_chunks) as f64 / 1e6
    );

    let mut table = Table::new(
        &format!("Hot-tier hit ratio — tier size vs Zipf skew ({accesses} accesses)"),
        &["skew s", "tier (% corpus)", "hits", "hit ratio", "device read (s)", "saved (MB)"],
    );
    // Serve-time telemetry: sample the tier every `window` accesses so
    // the hit/miss/eviction series can be plotted against offered load.
    let window = (accesses / 32).max(1);
    let mut top_decile_s1 = 0.0;
    let mut json_cells = String::new();
    for &skew in &[0.0, 0.5, 1.0, 1.5] {
        for &pct in &[0usize, 5, 10, 25, 50] {
            let mut store = KvStore::open(dir.path(), StorageProfile::ssd_9100pro())?;
            store.disable_throttle();
            store.set_hot_tier(per_chunk * n_chunks * pct / 100);
            // Per-cell registry + sampler on the access-index "clock":
            // one sample boundary per telemetry window, aligned with the
            // legacy tier series below.
            let reg = MetricsRegistry::new();
            if let Some(tier) = store.hot_tier() {
                register_tier(&reg, std::sync::Arc::clone(tier))?;
            }
            let mut sampler = Sampler::new(reg.clone(), window as f64);
            let zipf = Zipf::new(n_chunks, skew);
            let mut rng = Rng::new(1234);
            let (mut hits, mut device_secs) = (0u64, 0.0f64);
            for i in 0..accesses {
                let l = store.load(zipf.sample(&mut rng) as u64)?;
                hits += l.from_cache as u64;
                device_secs += l.device_secs;
                if (i + 1) % window == 0 {
                    if let Some(tier) = store.hot_tier() {
                        tier.sample();
                    }
                }
                sampler.advance_to((i + 1) as f64);
            }
            sampler.finish(accesses as f64);
            let ratio = hits as f64 / accesses as f64;
            if skew == 1.0 && pct == 10 {
                top_decile_s1 = ratio;
            }
            let saved = store
                .hot_tier()
                .map(|t| t.stats.bytes_saved.load(std::sync::atomic::Ordering::Relaxed))
                .unwrap_or(0);
            table.row(&[
                format!("{skew:.1}"),
                format!("{pct}%"),
                hits.to_string(),
                format!("{:.1}%", 100.0 * ratio),
                format!("{device_secs:.4}"),
                format!("{:.1}", saved as f64 / 1e6),
            ]);
            let series = store.hot_tier().map(|t| t.stats.series()).unwrap_or_default();
            let _ = write!(
                json_cells,
                "{}{{\"skew\":{skew},\"tier_pct\":{pct},\"hits\":{hits},\
                 \"hit_ratio\":{ratio:.6},\"device_secs\":{device_secs:.6},\
                 \"bytes_saved\":{saved},\"window\":{window},\"series\":{},\
                 \"metrics\":{}}}",
                if json_cells.is_empty() { "" } else { "," },
                series_to_json(&series),
                sampler.to_json(),
            );
        }
    }
    table.print();
    println!(
        "\ntop-decile tier under Zipf(1.0): {:.0}% of loads served from DRAM \
         (vs 10% for a uniform stream) — the popular mass the ten-day rule banks on.",
        100.0 * top_decile_s1
    );
    if let Some(path) = args.opt("json") {
        let doc = format!(
            "{{\"bench\":\"fig_tier_hit\",\"smoke\":{smoke},\"chunks\":{n_chunks},\
             \"accesses\":{accesses},\"chunk_tokens\":{seq},\"cells\":[{json_cells}]}}"
        );
        std::fs::write(path, doc)?;
        eprintln!("[fig_tier_hit] wrote {path}");
    }
    Ok(())
}

