//! Table VI + §V-C4 — answer fidelity and MatKV-vs-CacheBlend speed.
//!
//! Accuracy substitution (DESIGN.md): with seeded weights, gold-answer F1
//! is meaningless; the paper's actual question — how much does dropping
//! cross-document attention perturb outputs — is measured exactly as
//! token-F1 against the Vanilla reference. Expected ordering:
//! Vanilla (1.0) >= CacheBlend >= MatKV, all high.
//!
//! Speed: the paper reports MatKV's KV loading 37% faster and TTFT 41%
//! faster than CacheBlend (which must re-run partial prefill after
//! loading). We measure the same two phases.

use matkv::coordinator::baselines::{cacheblend_mode, mean_f1};
use matkv::coordinator::{Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::{ArchSpec, DeviceProfile, StorageProfile};
use matkv::util::bench::Table;
use matkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.usize("requests", 24);
    let h100 = DeviceProfile::h100();
    let ssd = StorageProfile::raid0_4x9100();
    let arch = ArchSpec::llama_3b();

    // top-5 retrieval over 512-token chunks (paper: top-5, LongBench)
    let sc = Scenario::build(ScenarioSpec {
        config: "tiny".into(),
        storage: StorageProfile::raid0_4x9100(),
        n_docs: 24,
        doc_tokens: 512,
        seed: 20,
        ..ScenarioSpec::default()
    })?;
    let reqs = sc.requests(n, 4, 12);

    let (vanilla, vm) = sc.engine.serve_all(&reqs, 4, ServeMode::Vanilla)?;
    let (matkv, mm) = sc.engine.serve_all(&reqs, 4, ServeMode::MatKv)?;
    let (blend, bm) = sc.engine.serve_all(&reqs, 4, cacheblend_mode(sc.doc_tokens))?;

    let mut acc = Table::new(
        &format!("Table VI analogue — output fidelity vs Vanilla, {n} reqs, top-4 chunks"),
        &["system", "token F1 vs Vanilla"],
    );
    acc.row(&["Vanilla".into(), format!("{:.3}", mean_f1(&vanilla, &vanilla))]);
    acc.row(&["MatKV".into(), format!("{:.3}", mean_f1(&vanilla, &matkv))]);
    acc.row(&["CacheBlend".into(), format!("{:.3}", mean_f1(&vanilla, &blend))]);
    acc.print();

    let mut speed = Table::new(
        "§V-C4 — MatKV vs CacheBlend speed (load + time-to-first-token)",
        &["system", "load (s)", "TTFT path (sim s)", "prefill steps cost"],
    );
    let ttft_of = |m: &matkv::coordinator::PhaseBreakdown| {
        m.load_secs_on(&arch, &ssd)
            + m.upload_secs_on(&arch, &h100)
            + m.prefill_secs_on(&arch, &h100)
    };
    for (name, m) in [("MatKV", &mm), ("CacheBlend", &bm), ("Vanilla", &vm)] {
        speed.row(&[
            name.to_string(),
            format!("{:.4}", m.load_secs_on(&arch, &ssd)),
            format!("{:.4}", ttft_of(m)),
            format!("{:.3}", m.prefill_secs_on(&arch, &h100)),
        ]);
    }
    speed.print();

    let m_ttft = ttft_of(&mm);
    let b_ttft = ttft_of(&bm);
    println!(
        "\npaper shape: MatKV TTFT {:.0}% faster than CacheBlend (paper: 41%); fidelity ordering \
         Vanilla >= CacheBlend >= MatKV.",
        100.0 * (1.0 - m_ttft / b_ttft)
    );
    Ok(())
}
