//! Fig 6 — batched inference, Vanilla vs MatKV, batch sizes 1..8
//! (paper: 1..10 over 200 requests on LLaMA-70B; our AOT buckets are
//! {1,2,4,8}). Shape to reproduce: prefill scales ~linearly with batch
//! while decode grows sublinearly, so past batch ~8 prefill dominates
//! and MatKV's advantage widens toward ~2x.

use matkv::coordinator::{Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::{ArchSpec, DeviceProfile, StorageProfile};
use matkv::util::bench::Table;
use matkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.usize("requests", 16);
    let config = args.str("config", "base");

    let sc = Scenario::build(ScenarioSpec {
        config: config.clone(),
        storage: StorageProfile::raid0_4x9100(),
        n_docs: 12,
        doc_tokens: 1024,
        seed: 6,
        ..ScenarioSpec::default()
    })?;
    let reqs = sc.requests(n, 2, 20);
    let h100 = DeviceProfile::h100();
    let ssd = StorageProfile::raid0_4x9100();
    let arch = ArchSpec::standin_for(&config);

    let mut table = Table::new(
        &format!("Fig 6 — batch scaling, {n} requests (2x1024 in, 20 out), simulated H100 seconds"),
        &["batch", "V prefill", "V decode", "V total", "M load", "M prefill", "M decode", "M total", "speedup"],
    );

    for batch in [1usize, 2, 4, 8] {
        let (_, v) = sc.engine.serve_all(&reqs, batch, ServeMode::Vanilla)?;
        let (_, m) = sc.engine.serve_all(&reqs, batch, ServeMode::MatKv)?;
        let v_total = v.total_secs_on(&arch, &h100, &ssd);
        let m_total = m.total_secs_on(&arch, &h100, &ssd);
        table.row(&[
            batch.to_string(),
            format!("{:.3}", v.prefill_secs_on(&arch, &h100)),
            format!("{:.3}", v.decode_secs_on(&arch, &h100)),
            format!("{:.3}", v_total),
            format!("{:.3}", m.load_secs_on(&arch, &ssd) + m.upload_secs_on(&arch, &h100)),
            format!("{:.3}", m.prefill_secs_on(&arch, &h100)),
            format!("{:.3}", m.decode_secs_on(&arch, &h100)),
            format!("{:.3}", m_total),
            format!("{:.2}x", v_total / m_total),
        ]);
    }
    table.print();
    println!("\npaper shape: speedup grows with batch size toward ~2x as prefill dominates.");
    Ok(())
}
