//! End-to-end integration tests: ingest → retrieve → load/recompute →
//! decode across serve modes, over the real AOT artifacts.
//!
//! These are the rust-level counterparts of python/tests/test_model.py's
//! equivalence invariants — exercised through the full coordinator stack
//! (tokenizer, vector DB, KV store, PJRT runtime).

use matkv::coordinator::baselines::{fidelity, mean_f1, token_f1};
use matkv::coordinator::{serve_overlapped, Engine, EngineOptions, ServeMode};
use matkv::vectordb::VectorIndex;
use matkv::hwsim::StorageProfile;
use matkv::kvstore::{KvFormat, KvStore};
use matkv::util::tempdir::TempDir;
use matkv::workload::{Corpus, RagRequest, RequestGen, TurboRagProfile};
use matkv::Manifest;

const DOC_TOKENS: usize = 512;

// Every test here executes models through PJRT over the real AOT
// artifacts; without them (python toolchain not run) the shared macro
// skips the test with a notice, so the pure-rust suites stay green.
use matkv::require_artifacts;

fn build_engine_with(
    n_docs: usize,
    tune: impl FnOnce(&mut KvStore),
) -> (TempDir, Corpus, Engine) {
    let m = Manifest::load(matkv::artifacts_dir()).expect("make artifacts first");
    let corpus = Corpus::generate(n_docs, DOC_TOKENS, n_docs.min(8), 11);
    let dir = TempDir::new("matkv-itest").unwrap();
    let mut kv = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
    tune(&mut kv);
    let opts = EngineOptions::for_config(&m, "tiny").unwrap();
    let engine = Engine::new(&m, opts, kv, corpus.texts()).unwrap();
    let stats = engine.ingest_corpus(&corpus, DOC_TOKENS).unwrap();
    assert_eq!(stats.docs, n_docs);
    assert_eq!(stats.tokens, n_docs * DOC_TOKENS);
    (dir, corpus, engine)
}

fn build_engine(n_docs: usize) -> (TempDir, Corpus, Engine) {
    build_engine_with(n_docs, |_| {})
}

fn requests(corpus: &Corpus, n: usize, top_k: usize, out: usize) -> Vec<RagRequest> {
    let mut gen = RequestGen::new(
        TurboRagProfile { top_k, query_tokens: 12.0, output_tokens: out },
        corpus.n_topics,
        1.0,
        5,
    );
    gen.take(corpus, n)
}

#[test]
fn ingest_materializes_every_doc() {
    require_artifacts!();
    let (_d, _c, engine) = build_engine(6);
    assert_eq!(engine.kv.len().unwrap(), 6);
    assert!(engine.kv.bytes_on_disk().unwrap() > 0);
    assert_eq!(engine.retrieval.index.read().unwrap().len(), 6);
}

#[test]
fn matkv_serves_batches_deterministically() {
    require_artifacts!();
    let (_d, corpus, engine) = build_engine(6);
    let reqs = requests(&corpus, 4, 2, 6);
    let (r1, m1) = engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
    let (r2, _m2) = engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
    assert_eq!(r1.len(), 4);
    for (a, b) in r1.iter().zip(&r2) {
        assert_eq!(a.request_id, b.request_id);
        assert_eq!(a.tokens, b.tokens, "nondeterministic generation");
        assert_eq!(a.tokens.len(), 6);
    }
    assert!(m1.loaded_bytes > 0);
    assert!(m1.load_device_secs >= 0.0);
    assert_eq!(m1.tokens_out, 24);
}

#[test]
fn single_doc_matkv_equals_vanilla_exactly() {
    require_artifacts!();
    // With one retrieved document there is no cross-document attention to
    // drop: MatKV must generate the *identical* token sequence as Vanilla.
    // Lossless (v1/f32) storage isolates the position-alignment claim
    // from f16 quantization; the default v2 format's fidelity is covered
    // statistically by `two_doc_modes_are_close_but_not_identical`.
    let (_d, corpus, engine) = build_engine_with(6, |kv| kv.set_format(KvFormat::V1));
    let reqs = requests(&corpus, 3, 1, 8);
    let (rv, _) = engine.serve_all(&reqs, 1, ServeMode::Vanilla).unwrap();
    let (rm, _) = engine.serve_all(&reqs, 1, ServeMode::MatKv).unwrap();
    for (v, m) in rv.iter().zip(&rm) {
        assert_eq!(v.retrieved, m.retrieved, "retrieval must agree");
        assert_eq!(v.tokens, m.tokens, "single-doc MatKV must equal Vanilla");
    }
}

#[test]
fn two_doc_modes_are_close_but_not_identical() {
    require_artifacts!();
    let (_d, corpus, engine) = build_engine(8);
    let reqs = requests(&corpus, 6, 2, 8);
    let (rv, _) = engine.serve_all(&reqs, 2, ServeMode::Vanilla).unwrap();
    let (rm, _) = engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
    let f1 = mean_f1(&rv, &rm);
    // same model, same docs: outputs correlate strongly but cross-doc
    // attention is missing -> not (necessarily) identical.
    assert!(f1 > 0.1, "MatKV fidelity collapsed: {f1}");
    // CacheBlend repairs some cross-attention; should not be *worse* than
    // MatKV by a wide margin.
    let (rc, _) = engine
        .serve_all(&reqs, 2, ServeMode::CacheBlend { recompute_tokens: 92 })
        .unwrap();
    let f1_cb = mean_f1(&rv, &rc);
    assert!(f1_cb > f1 - 0.25, "cacheblend {f1_cb} vs matkv {f1}");
}

#[test]
fn overlap_produces_identical_outputs() {
    require_artifacts!();
    let (_d, corpus, engine) = build_engine(8);
    let reqs = requests(&corpus, 6, 2, 5);
    let (plain, _) = engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
    let (ov, metrics, report) = serve_overlapped(&engine, &reqs, 2, ServeMode::MatKv).unwrap();
    assert_eq!(plain.len(), ov.len());
    for (a, b) in plain.iter().zip(&ov) {
        assert_eq!(a.tokens, b.tokens, "overlap changed results");
    }
    assert_eq!(report.batches, 3);
    assert!(metrics.total_wall_secs > 0.0);
    assert!(report.loader_busy_secs > 0.0);
}

#[test]
fn overlap_rejects_vanilla() {
    require_artifacts!();
    let (_d, corpus, engine) = build_engine(4);
    let reqs = requests(&corpus, 2, 1, 2);
    assert!(serve_overlapped(&engine, &reqs, 2, ServeMode::Vanilla).is_err());
}

#[test]
fn batch_padding_does_not_change_results() {
    require_artifacts!();
    // 3 requests in a batch of 4-bucket must match serving them 1-by-1.
    let (_d, corpus, engine) = build_engine(6);
    let reqs = requests(&corpus, 3, 2, 4);
    let (batched, _) = engine.serve_batch(&reqs, ServeMode::MatKv).unwrap();
    let mut solo = Vec::new();
    for r in &reqs {
        let (mut x, _) = engine.serve_batch(std::slice::from_ref(r), ServeMode::MatKv).unwrap();
        solo.append(&mut x);
    }
    for (a, b) in batched.iter().zip(&solo) {
        assert_eq!(a.tokens, b.tokens, "bucket padding leaked into results");
    }
}

#[test]
fn delete_doc_removes_everywhere() {
    require_artifacts!();
    let (_d, _corpus, engine) = build_engine(4);
    assert!(engine.delete_doc(1).unwrap());
    assert_eq!(engine.kv.len().unwrap(), 3);
    assert_eq!(engine.retrieval.index.read().unwrap().len(), 3);
    assert!(!engine.delete_doc(1).unwrap());
    // serving still works, retrieval just never returns doc 1
    let reqs = requests(&Corpus::generate(4, DOC_TOKENS, 4, 11), 2, 2, 3);
    let (r, _) = engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
    for resp in r {
        assert!(!resp.retrieved.contains(&1));
    }
}

#[test]
fn retrieval_is_topical() {
    require_artifacts!();
    let (_d, corpus, engine) = build_engine(8);
    // a query for topic t should retrieve the docs of topic t first
    let mut rng = matkv::workload::Rng::new(3);
    let mut hits = 0;
    for topic in 0..8 {
        let q = corpus.query_for_topic(topic, 12, &mut rng);
        let ids = engine.retrieval.retrieve(&q, 1);
        if corpus.docs[ids[0] as usize].topic == topic {
            hits += 1;
        }
    }
    assert!(hits >= 6, "retrieval precision {hits}/8");
}

#[test]
fn fidelity_metric_sane_on_engine_outputs() {
    require_artifacts!();
    let (_d, corpus, engine) = build_engine(4);
    let reqs = requests(&corpus, 2, 1, 6);
    let (r, _) = engine.serve_all(&reqs, 1, ServeMode::MatKv).unwrap();
    assert_eq!(token_f1(&r[0].tokens, &r[0].tokens), 1.0);
}

// ---------------------------------------------------------------------------
// failure injection & edge cases
// ---------------------------------------------------------------------------

#[test]
fn mismatched_config_kv_rejected() {
    require_artifacts!();
    // Materialize with tiny, then point a small-config engine at the same
    // KV store: the load path must refuse to splice foreign KVs.
    let m = Manifest::load(matkv::artifacts_dir()).unwrap();
    let corpus = Corpus::generate(4, 256, 4, 11);
    let dir = TempDir::new("matkv-xcfg").unwrap();
    {
        let kv = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
        let opts = EngineOptions::for_config(&m, "tiny").unwrap();
        let engine = Engine::new(&m, opts, kv, corpus.texts()).unwrap();
        engine.ingest_corpus(&corpus, 256).unwrap();
    }
    let kv = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
    let opts = EngineOptions::for_config(&m, "small").unwrap();
    let engine = Engine::new(&m, opts, kv, corpus.texts()).unwrap();
    // register embeddings so retrieval returns the foreign chunks
    {
        let mut ix = engine.retrieval.index.write().unwrap();
        for d in &corpus.docs {
            ix.insert(d.id, engine.retrieval.embedder.embed(
                &engine.retrieval.tokenizer.encode(&d.text)));
        }
    }
    let reqs = requests(&corpus, 1, 1, 2);
    let err = engine.serve_all(&reqs, 1, ServeMode::MatKv).unwrap_err();
    assert!(err.to_string().contains("different model config"), "{err}");
}

#[test]
fn missing_kv_file_is_clean_error() {
    require_artifacts!();
    let (_d, corpus, engine) = build_engine(4);
    // delete the file behind the vector DB's back
    engine.kv.delete(0).unwrap();
    engine.kv.delete(1).unwrap();
    engine.kv.delete(2).unwrap();
    engine.kv.delete(3).unwrap();
    let reqs = requests(&corpus, 1, 1, 2);
    let err = engine.serve_all(&reqs, 1, ServeMode::MatKv).unwrap_err();
    assert!(err.to_string().contains("loading KV"), "{err}");
    // Vanilla still works (recomputes from tokens)
    let (r, _) = engine.serve_all(&reqs, 1, ServeMode::Vanilla).unwrap();
    assert_eq!(r.len(), 1);
}

#[test]
fn context_overflow_is_clean_error() {
    require_artifacts!();
    // 5 x 512-token docs = 2560 > C=2304: splice must fail, not corrupt
    let (_d, corpus, engine) = build_engine(8);
    let reqs = requests(&corpus, 1, 5, 2);
    let err = engine.serve_all(&reqs, 1, ServeMode::MatKv).unwrap_err();
    assert!(err.to_string().contains("does not fit"), "{err}");
}

#[test]
fn hot_tier_serves_repeat_traffic_from_dram() {
    require_artifacts!();
    // Acceptance: with a hot tier big enough for the popular chunks,
    // repeated stage_matkv of the same requests reports cache hits and
    // strictly lower simulated device time than the cold pass.
    let (_d, corpus, engine) = build_engine_with(6, |kv| kv.set_hot_tier(256 << 20));
    let reqs = requests(&corpus, 4, 2, 4);
    let (r_cold, cold) = engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
    let (r_warm, warm) = engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
    assert!(cold.load_reads > 0, "first pass must miss to the device");
    assert!(warm.cache_hits > 0, "repeat pass must hit the hot tier");
    assert_eq!(warm.cache_hits + warm.load_reads, cold.cache_hits + cold.load_reads);
    assert!(warm.load_device_secs < cold.load_device_secs);
    assert!(warm.cache_bytes_saved > 0);
    assert_eq!(warm.loaded_tokens, cold.loaded_tokens, "hits still splice tokens");
    // the tier must not change what gets generated
    for (a, b) in r_cold.iter().zip(&r_warm) {
        assert_eq!(a.tokens, b.tokens, "hot tier changed results");
    }
    // and the overlap pipeline sees the same tier through the shared Arc
    let (r_ov, agg, _report) = serve_overlapped(&engine, &reqs, 2, ServeMode::MatKv).unwrap();
    assert!(agg.cache_hits > 0);
    for (a, b) in r_cold.iter().zip(&r_ov) {
        assert_eq!(a.tokens, b.tokens, "overlap + hot tier changed results");
    }
}

#[test]
fn warm_tier_serves_q8_chunks_with_high_fidelity() {
    require_artifacts!();
    // Pure-f32 reference deployment: a hot tier big enough that nothing
    // is ever quantized.
    let (_d1, corpus, f32_engine) = build_engine_with(6, |kv| kv.set_hot_tier(256 << 20));
    let reqs = requests(&corpus, 4, 2, 6);
    let (r_ref, _) = f32_engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();

    // q8 deployment: a hot tier of ~2 chunks forces the working set to
    // demote into the warm tier, so the repeat pass serves dequantized
    // planes. Same corpus seed + request seed → same retrieval, same
    // decode, only the storage plane differs.
    let m = Manifest::load(matkv::artifacts_dir()).unwrap();
    let cfg = m.config("tiny").unwrap();
    let chunk_bytes = std::mem::size_of::<matkv::kvstore::KvChunk>()
        + 8 * cfg.n_layers * cfg.n_kv_heads * DOC_TOKENS * cfg.head_dim;
    let (_d2, corpus2, q8_engine) = build_engine_with(6, |kv| {
        kv.set_hot_tier(2 * chunk_bytes);
        kv.set_warm_tier(256 << 20);
    });
    let reqs2 = requests(&corpus2, 4, 2, 6);
    q8_engine.serve_all(&reqs2, 2, ServeMode::MatKv).unwrap(); // fill + demote
    let (r_q8, wm) = q8_engine.serve_all(&reqs2, 2, ServeMode::MatKv).unwrap();

    assert!(wm.warm_hits > 0, "repeat pass must be served from the warm tier");
    assert!(wm.dequant_secs > 0.0, "warm hits must charge modeled dequant time");
    assert!(wm.warm_bytes_saved > 0);
    assert!(
        wm.load_reads < r_q8.len() * 2,
        "warm tier must absorb device reads: {} reads",
        wm.load_reads
    );
    // Table-VI shape: q8-served outputs stay close to the pure-f32 run.
    // 0.95 is the PR's acceptance bar; the bench reports the exact
    // deltas, this enforces them (everything here is deterministic —
    // seeded weights, greedy decode — so the bound is not flaky).
    let f = fidelity(&r_ref, &r_q8);
    assert_eq!(f.pairs, 4);
    assert!(f.mean_f1 >= 0.95, "q8-served fidelity below the acceptance bar: {f:?}");
}

#[test]
fn vanilla_context_budget_guard() {
    require_artifacts!();
    let (_d, corpus, engine) = build_engine(6);
    // 5 x 512 doc tokens alone exceed C=2304: prefill must bail before
    // stepping past the cache.
    let reqs = requests(&corpus, 1, 5, 2);
    let err = engine.serve_all(&reqs, 1, ServeMode::Vanilla).unwrap_err();
    assert!(err.to_string().contains("exceeds serve context"), "{err}");
    // 4 x 512 docs fit, but the decode budget pushes past C.
    let reqs = requests(&corpus, 1, 4, 400);
    let err = engine.serve_all(&reqs, 1, ServeMode::Vanilla).unwrap_err();
    assert!(err.to_string().contains("exceeds serve context"), "{err}");
}

#[test]
fn early_decode_break_counts_actual_tokens() {
    require_artifacts!();
    // MatKV with 4 x 512 spliced docs leaves < 400 decode slots in
    // C=2304: decode breaks early and tokens_out must report what was
    // generated, not the requested budget.
    let (_d, corpus, engine) = build_engine(6);
    let reqs = requests(&corpus, 1, 4, 400);
    let (r, m) = engine.serve_all(&reqs, 1, ServeMode::MatKv).unwrap();
    assert!(!r[0].tokens.is_empty());
    assert!(r[0].tokens.len() < 400, "decode did not break early: {}", r[0].tokens.len());
    assert_eq!(m.tokens_out, r[0].tokens.len(), "tokens_out overstates generation");
}

#[test]
fn scheduler_integrates_with_engine() {
    require_artifacts!();
    use matkv::coordinator::{BatchPolicy, ExecOptions, SchedOptions, SchedPolicy, Scheduler};
    let (_d, corpus, engine) = build_engine(6);
    let mut sched = Scheduler::new(
        engine.loader_ctx(),
        SchedOptions {
            batch: BatchPolicy { max_batch: 4, max_wait_secs: 0.0 },
            policy: SchedPolicy::Fifo,
            service_estimate_secs: 0.0,
            estimator: None,
        },
    );
    sched.enqueue_now(requests(&corpus, 10, 1, 3));
    let out = sched.run(&engine, ServeMode::MatKv, &ExecOptions::sequential()).unwrap();
    assert_eq!(out.responses.len(), 10);
    assert_eq!(out.sched.requests, 10);
    assert_eq!(out.sched.batches, 3); // 4 + 4 + 2
    assert_eq!(out.metrics.requests, 10);
}

#[test]
fn affinity_scheduling_preserves_per_request_outputs() {
    require_artifacts!();
    // Batch composition must not change what a request generates (the
    // same invariant batch_padding_does_not_change_results pins): an
    // affinity-reordered schedule yields the same tokens per request id
    // as the fifo schedule, just possibly in a different order.
    use matkv::coordinator::{BatchPolicy, ExecOptions, SchedOptions, SchedPolicy, Scheduler};
    use std::collections::HashMap;
    let (_d, corpus, engine) = build_engine_with(6, |kv| kv.set_hot_tier(256 << 20));
    let reqs = requests(&corpus, 8, 2, 4);
    let (fifo, _) = engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
    let mut sched = Scheduler::new(
        engine.loader_ctx(),
        SchedOptions {
            batch: BatchPolicy { max_batch: 2, max_wait_secs: 0.0 },
            policy: SchedPolicy::TierAffinity { max_age_batches: 4 },
            service_estimate_secs: 0.0,
            estimator: None,
        },
    );
    sched.enqueue_now(reqs.clone());
    let out = sched.run(&engine, ServeMode::MatKv, &ExecOptions::sequential()).unwrap();
    assert_eq!(out.responses.len(), fifo.len());
    let by_id: HashMap<u64, &matkv::coordinator::Response> =
        fifo.iter().map(|r| (r.request_id, r)).collect();
    for r in &out.responses {
        let want = by_id.get(&r.request_id).expect("every request served once");
        assert_eq!(r.tokens, want.tokens, "affinity batching changed request {}", r.request_id);
        assert_eq!(r.retrieved, want.retrieved);
    }
}

#[test]
fn sharded_store_end_to_end_with_prefetch() {
    require_artifacts!();
    // Full serve path over a 4-shard JBOD with a hot tier: ingest lands
    // chunks across shard dirs, overlapped+prefetched serving produces
    // the same tokens as the plain path, and the per-shard rollup in
    // PhaseBreakdown accounts for every device read.
    use matkv::coordinator::{serve_overlapped_with, OverlapOptions};
    let m = Manifest::load(matkv::artifacts_dir()).unwrap();
    let corpus = Corpus::generate(8, DOC_TOKENS, 8, 11);
    let dir = TempDir::new("matkv-itest-shard").unwrap();
    let mut kv = KvStore::open_sharded(dir.path(), StorageProfile::dram(), 4).unwrap();
    kv.set_hot_tier(256 << 20);
    let opts = EngineOptions::for_config(&m, "tiny").unwrap();
    let engine = Engine::new(&m, opts, kv, corpus.texts()).unwrap();
    engine.ingest_corpus(&corpus, DOC_TOKENS).unwrap();
    assert_eq!(engine.kv.len().unwrap(), 8);
    assert!(engine.kv.shards().iter().filter(|s| s.stats.writes.load(
        std::sync::atomic::Ordering::Relaxed) > 0).count() > 1,
        "ingest should spread materialized chunks across shards");

    let reqs = requests(&corpus, 6, 2, 4);
    let (plain, pm) = engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
    assert_eq!(pm.shard_reads.iter().sum::<u64>() as usize, pm.load_reads);
    let ov_opts = OverlapOptions { prefetch: true, lookahead: 2 };
    let (ov, om, rep) =
        serve_overlapped_with(&engine, &reqs, 2, ServeMode::MatKv, &ov_opts).unwrap();
    assert_eq!(rep.prefetch_absent, 0);
    assert!(om.cache_hits > 0, "repeat traffic should hit the warm tier");
    for (a, b) in plain.iter().zip(&ov) {
        assert_eq!(a.tokens, b.tokens, "sharding/prefetch changed results");
    }
}

#[test]
fn work_traces_accumulate_sanely() {
    require_artifacts!();
    let (_d, corpus, engine) = build_engine(6);
    let reqs = requests(&corpus, 2, 2, 5);
    let (_, v) = engine.serve_all(&reqs, 2, ServeMode::Vanilla).unwrap();
    let (_, m) = engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
    // Vanilla prefilled 2 docs x 512 + query per request; MatKV only the query
    assert!(v.prefill_trace.sum_s > 2.0 * 2.0 * 512.0);
    assert!(m.prefill_trace.sum_s < 100.0);
    // MatKV loaded what Vanilla recomputed
    assert_eq!(m.loaded_tokens, 2 * 2 * 512);
    // decode work identical across modes
    assert_eq!(v.decode_trace.steps, m.decode_trace.steps);
}
