//! Table II / Table VI companion: output fidelity of MatKV vs Vanilla.
//!
//! Generates answers for the same queries under full cross-document
//! attention (Vanilla), independent per-document KVs (MatKV), and partial
//! recompute (CacheBlend-style), printing side-by-side samples (Table II)
//! and aggregate token-F1 / prefix-agreement (the Table VI question
//! restated for seeded weights — see DESIGN.md Substitutions).
//!
//! Run: `cargo run --release --example fidelity`

use matkv::coordinator::baselines::{cacheblend_mode, mean_f1, prefix_agreement, token_f1};
use matkv::coordinator::{Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::StorageProfile;
use matkv::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let sc = Scenario::build(ScenarioSpec {
        config: "tiny".into(),
        storage: StorageProfile::dram(),
        n_docs: 16,
        doc_tokens: 512,
        seed: 21,
        ..ScenarioSpec::default()
    })?;
    let reqs = sc.requests(12, 2, 12);

    let (vanilla, _) = sc.engine.serve_all(&reqs, 4, ServeMode::Vanilla)?;
    let (matkv, _) = sc.engine.serve_all(&reqs, 4, ServeMode::MatKv)?;
    let (blend, _) = sc.engine.serve_all(&reqs, 4, cacheblend_mode(sc.doc_tokens))?;

    // Table II analogue: sample side-by-side generations
    println!("=== Table II analogue — sample generations ===");
    for i in 0..3 {
        println!("\nQ{}: {:?}", reqs[i].id, reqs[i].query);
        println!("  Vanilla : {}", vanilla[i].text);
        println!("  MatKV   : {}", matkv[i].text);
        println!(
            "  (F1 {:.2}, agree on first {} tokens)",
            token_f1(&vanilla[i].tokens, &matkv[i].tokens),
            prefix_agreement(&vanilla[i].tokens, &matkv[i].tokens)
        );
    }

    // Table VI analogue: aggregate fidelity vs the Vanilla reference
    let mut table = Table::new(
        "Table VI analogue — output fidelity vs Vanilla (token F1)",
        &["system", "mean F1", "mean prefix agreement"],
    );
    for (name, responses) in [("Vanilla", &vanilla), ("MatKV", &matkv), ("CacheBlend", &blend)] {
        let f1 = mean_f1(&vanilla, responses);
        let prefix: f64 = vanilla
            .iter()
            .zip(responses.iter())
            .map(|(a, b)| prefix_agreement(&a.tokens, &b.tokens) as f64)
            .sum::<f64>()
            / vanilla.len() as f64;
        table.row(&[name.to_string(), format!("{f1:.3}"), format!("{prefix:.1}")]);
    }
    table.print();
    println!(
        "\npaper shape: Vanilla == 1.0 by construction; CacheBlend >= MatKV \
         (partial cross-attention repair); both well above 0."
    );
    Ok(())
}
