//! Fig 10 scenario: prefill with MatKV, decode on a low-end GPU.
//!
//! MatKV decouples prefill from decode, so a $1.6K RTX 4090 + SSD can
//! serve what normally needs a $50K H100: the materialized KVs replace
//! the compute-bound prefill, and decode is memory-bound (much less
//! sensitive to GPU class). This example drives the real pipeline once
//! and converts the phase costs to both device profiles.
//!
//! Run: `cargo run --release --example lowend_decode`

use matkv::coordinator::{Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::{ArchSpec, DeviceProfile, StorageProfile};
use matkv::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let sc = Scenario::build(ScenarioSpec {
        config: "small".into(),
        storage: StorageProfile::raid0_4x9100(), // H100 box storage
        n_docs: 12,
        doc_tokens: 1024,
        seed: 3,
        ..ScenarioSpec::default()
    })?;
    let reqs = sc.requests(16, 1, 20);

    // Drive the real pipeline once per mode to collect phase costs.
    let (_, vanilla) = sc.engine.serve_all(&reqs, 8, ServeMode::Vanilla)?;
    let (_, matkv) = sc.engine.serve_all(&reqs, 8, ServeMode::MatKv)?;

    let h100 = DeviceProfile::h100();
    let r4090 = DeviceProfile::rtx4090();
    let raid = StorageProfile::raid0_4x9100();
    let pm9a3 = StorageProfile::ssd_pm9a3(); // the 4090 box's SSD
    let arch = ArchSpec::llama_8b(); // small stands in for LLaMA-8B

    // Simulated end-to-end per configuration (Fig 10's four bars).
    let rows: Vec<(String, f64)> = vec![
        (
            "Vanilla @ H100".into(),
            vanilla.prefill_secs_on(&arch, &h100) + vanilla.decode_secs_on(&arch, &h100),
        ),
        ("MatKV   @ H100".into(), matkv.total_secs_on(&arch, &h100, &raid)),
        (
            "Vanilla @ 4090".into(),
            vanilla.prefill_secs_on(&arch, &r4090) + vanilla.decode_secs_on(&arch, &r4090),
        ),
        ("MatKV   @ 4090".into(), matkv.total_secs_on(&arch, &r4090, &pm9a3)),
    ];

    let h100_vanilla = rows[0].1;
    let mut table = Table::new(
        "Fig 10 — MatKV vs full recompute across GPU classes (simulated)",
        &["configuration", "time (s)", "vs Vanilla@H100", "hw cost"],
    );
    for (name, secs) in &rows {
        let cost = if name.contains("H100") { "$50,000" } else { "$1,600" };
        table.row(&[
            name.clone(),
            format!("{secs:.4}"),
            format!("{:.2}x", secs / h100_vanilla),
            cost.to_string(),
        ]);
    }
    table.print();

    let matkv_4090 = rows[3].1;
    let vanilla_4090 = rows[2].1;
    println!(
        "\npaper shape check: MatKV@4090 is {:.1}x slower than Vanilla@H100 (paper: ~1.5x)\n\
         while Vanilla@4090 is {:.1}x slower (paper: ~3x) — at 1/30th the GPU cost.",
        matkv_4090 / h100_vanilla,
        vanilla_4090 / h100_vanilla
    );
    Ok(())
}
