//! Quickstart: the complete MatKV lifecycle in ~40 lines.
//!
//! 1. Generate a small corpus and build an engine (tiny model config).
//! 2. Ingest: embed documents into the vector DB, prefill their KV caches
//!    on the device, materialize them to (simulated) flash.
//! 3. Serve: retrieve top-2 documents per query, *load* their KVs instead
//!    of recomputing prefill, decode an answer.
//!
//! Run: `cargo run --release --example quickstart`

use matkv::coordinator::{Engine, EngineOptions, ServeMode};
use matkv::hwsim::StorageProfile;
use matkv::kvstore::KvStore;
use matkv::util::tempdir::TempDir;
use matkv::workload::{Corpus, RequestGen, TurboRagProfile};
use matkv::Manifest;

fn main() -> anyhow::Result<()> {
    // 1. corpus + engine
    let manifest = Manifest::load(matkv::artifacts_dir())?;
    let corpus = Corpus::generate(/*docs=*/ 12, /*tokens=*/ 512, /*topics=*/ 6, /*seed=*/ 1);
    let kv_dir = TempDir::new("matkv-quickstart")?;
    let kv = KvStore::open(kv_dir.path(), StorageProfile::ssd_9100pro())?;
    let engine =
        Engine::new(&manifest, EngineOptions::for_config(&manifest, "tiny")?, kv, corpus.texts())?;

    // 2. ingest (Fig 3a): prefill once, materialize KVs on flash
    let stats = engine.ingest_corpus(&corpus, 512)?;
    println!(
        "ingested {} docs ({} tokens) -> {:.1} MB of materialized KV",
        stats.docs,
        stats.tokens,
        stats.materialized_bytes as f64 / 1e6
    );

    // 3. serve (Fig 3b): load KVs from flash, skip document prefill
    let mut gen = RequestGen::new(TurboRagProfile::default(), corpus.n_topics, 1.0, 9);
    let requests = gen.take(&corpus, 4);
    let (responses, metrics) = engine.serve_all(&requests, 2, ServeMode::MatKv)?;

    for r in &responses {
        println!("Q{} retrieved docs {:?} -> \"{}\"", r.request_id, r.retrieved, r.text);
    }
    println!(
        "\nphases: load {:.1} ms (device {:.1} ms) | prefill {:.1} ms | decode {:.1} ms",
        metrics.load_wall_secs * 1e3,
        metrics.load_device_secs * 1e3,
        metrics.prefill_wall_secs * 1e3,
        metrics.decode_wall_secs * 1e3,
    );
    Ok(())
}
