//! End-to-end serving driver (the EXPERIMENTS.md headline run).
//!
//! Loads a real (small, seeded-weight) LLaMA-architecture model through
//! the AOT artifacts, ingests a corpus, then serves a batched TurboRAG
//! workload three ways — Vanilla recompute, MatKV, MatKV+overlap —
//! reporting measured latency/throughput per phase, simulated H100 time,
//! and whole-server energy (Tables IV/V methodology).
//!
//! Run: `cargo run --release --example e2e_serving -- [--config small]
//!       [--requests 32] [--batch 8] [--docs 24] [--out 20]`

use matkv::coordinator::{serve_overlapped, Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::{ArchSpec, DeviceProfile, EnergyMeter, PhaseKind, StorageProfile};
use matkv::util::bench::{fmt_secs, Table};
use matkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let config = args.str("config", "small");
    let n_requests = args.usize("requests", 32);
    let batch = args.usize("batch", 8);
    let n_docs = args.usize("docs", 24);
    let out_tokens = args.usize("out", 20);

    eprintln!("[e2e] building scenario: config={config} docs={n_docs} x 1024 tokens");
    let sc = Scenario::build(ScenarioSpec {
        config: config.clone(),
        storage: StorageProfile::raid0_4x9100(),
        n_docs,
        doc_tokens: 1024,
        seed: 42,
        ..ScenarioSpec::default()
    })?;
    let reqs = sc.requests(n_requests, 2, out_tokens);
    let h100 = DeviceProfile::h100();
    let ssd = StorageProfile::raid0_4x9100();
    let arch = ArchSpec::standin_for(&config);

    let mut table = Table::new(
        &format!("e2e serving — {config}, {n_requests} reqs (2x1024 tok docs, {out_tokens} out), batch {batch}"),
        &["mode", "wall", "load", "prefill", "decode", "tok/s", "simH100", "sys kJ"],
    );

    for (name, mode, overlap) in [
        ("Vanilla", ServeMode::Vanilla, false),
        ("MatKV", ServeMode::MatKv, false),
        ("MatKV+OL", ServeMode::MatKv, true),
    ] {
        let (responses, m) = if overlap {
            let (r, m, rep) = serve_overlapped(&sc.engine, &reqs, batch, mode)?;
            eprintln!(
                "[overlap] loader busy {:.2}s exec busy {:.2}s stall {:.3}s over {} batches",
                rep.loader_busy_secs, rep.exec_busy_secs, rep.exec_stall_secs, rep.batches
            );
            (r, m)
        } else {
            sc.engine.serve_all(&reqs, batch, mode)?
        };
        assert_eq!(responses.len(), n_requests);

        // Tables IV/V methodology: integrate simulated device power over
        // simulated phase times (at stand-in architecture scale).
        let mut meter = EnergyMeter::h100_server(StorageProfile::raid0_4x9100());
        let gpu_s = m.prefill_secs_on(&arch, &h100)
            + m.decode_secs_on(&arch, &h100)
            + m.upload_secs_on(&arch, &h100);
        let io_s = m.load_secs_on(&arch, &ssd);
        if overlap {
            let hidden = io_s.min(gpu_s);
            meter.record(PhaseKind::Overlapped, hidden);
            meter.record(PhaseKind::StorageIo, io_s - hidden);
            meter.record(PhaseKind::GpuCompute, gpu_s - hidden);
        } else {
            meter.record(PhaseKind::StorageIo, io_s);
            meter.record(PhaseKind::GpuCompute, gpu_s);
        }
        let energy = meter.system_report();

        table.row(&[
            name.to_string(),
            fmt_secs(m.total_wall_secs),
            fmt_secs(m.load_wall_secs),
            fmt_secs(m.prefill_wall_secs),
            fmt_secs(m.decode_wall_secs),
            format!("{:.1}", m.throughput()),
            fmt_secs(io_s + gpu_s),
            format!("{:.3}", energy.total_kj),
        ]);
    }
    table.print();

    println!("\nsession stats: {:?}", sc.engine.session.stats.borrow());
    Ok(())
}
