//! The paper's economic analysis end-to-end: Eq. 1 / the ten-day rule,
//! evaluated both at the paper's anchor point (H100 + LLaMA-70B + 9100
//! Pro) and for *this repo's* measured configs, plus the Fig-1 trend.
//!
//! Run: `cargo run --release --example economics`

use matkv::hwsim::economics::fig1_trend;
use matkv::hwsim::roofline::append_cost;
use matkv::hwsim::{DeviceProfile, StorageProfile, TenDayRule};
use matkv::util::bench::Table;
use matkv::Manifest;

fn main() -> anyhow::Result<()> {
    // --- paper anchor ------------------------------------------------------
    let anchor = TenDayRule::paper_anchor();
    println!("Ten-day rule @ paper anchor (LLaMA-70B, 1,024-token chunk):");
    println!("  GPU recompute cost : ${:.6}/access (amortized H100 seconds)", anchor.recompute_cost_usd());
    println!("  flash holding cost : ${:.4} ({} MB on a 9100 Pro)", anchor.storage_cost_usd(), anchor.kv_bytes >> 20);
    println!("  break-even interval: {:.1} days  <-- the ten-day rule", anchor.break_even_days());
    println!("  accessed hourly    : {:.0}x cheaper than recompute", anchor.cost_ratio_at_interval(3600.0));

    // --- our configs, simulated prefill times ------------------------------
    let m = Manifest::load(matkv::artifacts_dir())?;
    let h100 = DeviceProfile::h100();
    let mut table = Table::new(
        "break-even per model config (1,024-token chunk, H100 + 9100 Pro)",
        &["config", "prefill(sim)", "KV MB", "break-even days"],
    );
    for (name, cfg) in &m.configs {
        let prefill = append_cost(cfg, 1, 1024, 1024).secs_on(&h100);
        let rule = TenDayRule::for_config(
            cfg,
            1024,
            prefill,
            h100.clone(),
            StorageProfile::ssd_9100pro(),
        );
        table.row(&[
            name.clone(),
            format!("{:.2} ms", prefill * 1e3),
            format!("{:.1}", rule.kv_bytes as f64 / 1e6),
            format!("{:.1}", rule.break_even_days()),
        ]);
    }
    table.print();

    // --- Fig 1 trend --------------------------------------------------------
    let mut trend = Table::new(
        "Fig 1 — GPU vs SSD cost/performance trend",
        &["year", "gpu", "TFLOPs/k$", "ssd", "GB/s / ($/GB)", "GB/$"],
    );
    for r in fig1_trend() {
        trend.row(&[
            r.year.to_string(),
            r.gpu.to_string(),
            format!("{:.1}", r.gpu_tflops_per_kusd),
            r.ssd.to_string(),
            format!("{:.0}", r.ssd_gbps_per_kusd_tb),
            format!("{:.1}", r.ssd_gb_per_usd),
        ]);
    }
    trend.print();
    println!("\npaper claim preserved: SSD value (GB/$) improves faster than GPU value (TFLOPs/$).");
    Ok(())
}
