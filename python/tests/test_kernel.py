"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (batch, heads, GQA group, S/C lengths, head_dim,
block shapes, cache offsets); assert_allclose against the reference is the
core correctness signal for everything the rust runtime later executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention, vmem_footprint
from compile.kernels.rmsnorm import rmsnorm
from compile.kernels.ref import flash_attention_ref, rmsnorm_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def assert_attn_matches(b, h, h_kv, s, c, d, off, block_q, block_k):
    q = rand(0, (b, h, s, d))
    k = rand(1, (b, h_kv, c, d))
    v = rand(2, (b, h_kv, c, d))
    off = jnp.asarray(off, jnp.int32)
    out = flash_attention(q, k, v, off, block_q=block_q, block_k=block_k)
    ref = flash_attention_ref(q, k, v, off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


class TestFlashAttentionBasic:
    def test_decode_shape(self):
        # S=1 decode step over a big cache
        assert_attn_matches(2, 4, 2, 1, 256, 32, [100, 255], 1, 64)

    def test_prefill_from_empty(self):
        assert_attn_matches(1, 4, 2, 64, 64, 32, [0], 32, 32)

    def test_append_mid_cache(self):
        assert_attn_matches(2, 8, 2, 32, 512, 64, [64, 300], 32, 128)

    def test_mqa_group_one(self):
        # h == h_kv: plain MHA path through the same index map
        assert_attn_matches(1, 4, 4, 16, 128, 16, [50], 16, 32)

    def test_extreme_gqa(self):
        # 8 query heads sharing 1 kv head
        assert_attn_matches(1, 8, 1, 16, 128, 32, [10], 16, 64)

    def test_per_batch_offsets_differ(self):
        assert_attn_matches(4, 4, 2, 8, 256, 32, [0, 1, 128, 248], 8, 64)

    def test_single_block(self):
        # whole problem in one grid step (no online-softmax carry)
        assert_attn_matches(1, 2, 2, 16, 16, 8, [0], 16, 16)

    def test_block_q_larger_than_needed_rows(self):
        # garbage rows (i >= live) still produce finite output
        q = rand(0, (1, 2, 8, 16))
        k = rand(1, (1, 2, 64, 16))
        v = rand(2, (1, 2, 64, 16))
        out = flash_attention(q, k, v, jnp.array([5], jnp.int32), block_q=8, block_k=32)
        assert np.isfinite(np.asarray(out)).all()

    def test_values_deterministic(self):
        a = flash_attention(rand(0, (1, 2, 8, 16)), rand(1, (1, 2, 32, 16)),
                            rand(2, (1, 2, 32, 16)), jnp.array([4], jnp.int32),
                            block_q=8, block_k=16)
        b = flash_attention(rand(0, (1, 2, 8, 16)), rand(1, (1, 2, 32, 16)),
                            rand(2, (1, 2, 32, 16)), jnp.array([4], jnp.int32),
                            block_q=8, block_k=16)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_mask_excludes_future(self):
        # Perturbing cache slots beyond off+S must not change the output.
        q = rand(0, (1, 2, 4, 16))
        k = rand(1, (1, 2, 64, 16))
        v = rand(2, (1, 2, 64, 16))
        off = jnp.array([8], jnp.int32)
        base = flash_attention(q, k, v, off, block_q=4, block_k=16)
        k2 = k.at[:, :, 20:].set(1e6)
        v2 = v.at[:, :, 20:].set(-1e6)
        pert = flash_attention(q, k2, v2, off, block_q=4, block_k=16)
        np.testing.assert_allclose(np.asarray(base), np.asarray(pert), rtol=1e-6)

    def test_softmax_rows_sum_to_one_property(self):
        # With v = ones, output must be exactly ones (softmax normalizes).
        q = rand(0, (2, 4, 8, 32))
        k = rand(1, (2, 2, 128, 32))
        v = jnp.ones((2, 2, 128, 32), jnp.float32)
        out = flash_attention(q, k, v, jnp.array([3, 60], jnp.int32),
                              block_q=8, block_k=32)
        np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h_kv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    s_pow=st.integers(0, 5),
    c_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32, 64]),
    off_seed=st.integers(0, 10_000),
)
def test_flash_attention_hypothesis(b, h_kv, group, s_pow, c_blocks, d, off_seed):
    s = 2 ** s_pow
    block_k = 32
    c = max(c_blocks * block_k, s)
    rng = np.random.RandomState(off_seed)
    off = rng.randint(0, c - s + 1, size=b)
    assert_attn_matches(b, h_kv * group, h_kv, s, c, d, off.tolist(),
                        min(s, 16), block_k)


class TestRmsNorm:
    def test_matches_ref_2d(self):
        x = rand(0, (37, 64))
        w = rand(1, (64,))
        np.testing.assert_allclose(np.asarray(rmsnorm(x, w)),
                                   np.asarray(rmsnorm_ref(x, w)),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_ref_3d(self):
        x = rand(0, (3, 17, 32))
        w = rand(1, (32,))
        np.testing.assert_allclose(np.asarray(rmsnorm(x, w)),
                                   np.asarray(rmsnorm_ref(x, w)),
                                   rtol=2e-5, atol=2e-5)

    def test_scale_invariance_property(self):
        # rmsnorm(a*x) == rmsnorm(x) for a > 0 (up to eps)
        x = rand(0, (8, 128)) * 10
        w = jnp.ones((128,))
        a = rmsnorm(x, w)
        b = rmsnorm(x * 7.5, w)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 300), d=st.sampled_from([16, 32, 64, 128]),
           seed=st.integers(0, 100))
    def test_hypothesis_rows(self, n, d, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
        w = jax.random.normal(jax.random.PRNGKey(seed + 1), (d,))
        np.testing.assert_allclose(np.asarray(rmsnorm(x, w)),
                                   np.asarray(rmsnorm_ref(x, w)),
                                   rtol=3e-5, atol=3e-5)


def test_vmem_footprint_within_budget():
    # Default block shapes must fit comfortably in a 16 MiB TPU VMEM.
    assert vmem_footprint(128, 256, 64) < 2 * 1024 * 1024


class TestDenseAttention:
    """The batch-grid serving kernel must agree with the same oracle."""

    def test_matches_ref_basic(self):
        from compile.kernels.dense_attention import dense_attention
        q = rand(0, (2, 4, 8, 32))
        k = rand(1, (2, 2, 128, 32))
        v = rand(2, (2, 2, 128, 32))
        off = jnp.array([0, 100], jnp.int32)
        np.testing.assert_allclose(np.asarray(dense_attention(q, k, v, off)),
                                   np.asarray(flash_attention_ref(q, k, v, off)),
                                   rtol=3e-5, atol=3e-5)

    def test_matches_flash_kernel(self):
        # the two Pallas kernels must agree with each other, not only ref
        from compile.kernels.dense_attention import dense_attention
        q = rand(3, (1, 8, 16, 64))
        k = rand(4, (1, 2, 256, 64))
        v = rand(5, (1, 2, 256, 64))
        off = jnp.array([100], jnp.int32)
        a = dense_attention(q, k, v, off)
        b = flash_attention(q, k, v, off, block_q=16, block_k=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5)

    def test_decode_shape(self):
        from compile.kernels.dense_attention import dense_attention
        q = rand(0, (4, 4, 1, 32))
        k = rand(1, (4, 2, 512, 32))
        v = rand(2, (4, 2, 512, 32))
        off = jnp.array([0, 1, 300, 511], jnp.int32)
        np.testing.assert_allclose(np.asarray(dense_attention(q, k, v, off)),
                                   np.asarray(flash_attention_ref(q, k, v, off)),
                                   rtol=3e-5, atol=3e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 3),
        h_kv=st.sampled_from([1, 2]),
        group=st.sampled_from([1, 2, 4]),
        s_pow=st.integers(0, 4),
        c_blocks=st.integers(1, 4),
        d=st.sampled_from([8, 16, 32]),
        off_seed=st.integers(0, 10_000),
    )
    def test_hypothesis(self, b, h_kv, group, s_pow, c_blocks, d, off_seed):
        from compile.kernels.dense_attention import dense_attention
        s = 2 ** s_pow
        c = max(c_blocks * 32, s)
        rng = np.random.RandomState(off_seed)
        off = jnp.asarray(rng.randint(0, c - s + 1, size=b), jnp.int32)
        q = rand(0, (b, h_kv * group, s, d))
        k = rand(1, (b, h_kv, c, d))
        v = rand(2, (b, h_kv, c, d))
        np.testing.assert_allclose(np.asarray(dense_attention(q, k, v, off)),
                                   np.asarray(flash_attention_ref(q, k, v, off)),
                                   rtol=3e-5, atol=3e-5)
