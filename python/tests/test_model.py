"""L2 model invariants — these validate the serving recipes the rust
coordinator later reimplements over the AOT artifacts:

  * chunked prefill == one-shot prefill (the Vanilla baseline recipe);
  * single-document MatKV == Vanilla exactly (KV reuse is lossless when
    there is no cross-document attention to drop);
  * bucket padding never leaks into results;
  * cache slots past the live length are never observable.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import ModelConfig, CONFIGS
from compile import model as M

CFG = ModelConfig("mini", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=128, vocab=97, max_ctx=96)
P = M.init_params(CFG, seed=1)


def toks(seed, b, s, vocab=97):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, vocab)


def full(b):
    return M.empty_cache(CFG, b)


class TestAppendStep:
    def test_shapes(self):
        kc, vc = full(2)
        lg, k, v, ln = M.append_step(CFG, P, toks(0, 2, 8), jnp.array([8, 8]),
                                     kc, vc, jnp.zeros(2, jnp.int32))
        assert lg.shape == (2, CFG.vocab)
        assert k.shape == (CFG.n_layers, 2, CFG.n_kv_heads, CFG.max_ctx, CFG.head_dim)
        assert list(np.asarray(ln)) == [8, 8]

    def test_chunked_equals_oneshot(self):
        t = toks(1, 2, 32)
        kc, vc = full(2)
        z = jnp.zeros(2, jnp.int32)
        lg1, k1, v1, _ = M.append_step(CFG, P, t, jnp.array([32, 32]), kc, vc, z)
        kA, vA, lA = kc, vc, z
        for i in range(4):
            lg2, kA, vA, lA = M.append_step(CFG, P, t[:, i * 8:(i + 1) * 8],
                                            jnp.array([8, 8]), kA, vA, lA)
        np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(k1[:, :, :, :32]),
                                   np.asarray(kA[:, :, :, :32]), rtol=1e-4, atol=1e-4)

    def test_bucket_padding_invariance(self):
        # Same 10 live tokens, S=16 bucket with two different pad contents.
        t = toks(2, 1, 10)
        pad_a = jnp.concatenate([t, jnp.zeros((1, 6), jnp.int32)], axis=1)
        pad_b = jnp.concatenate([t, jnp.full((1, 6), 7, jnp.int32)], axis=1)
        kc, vc = full(1)
        z = jnp.zeros(1, jnp.int32)
        ql = jnp.array([10], jnp.int32)
        lg_a, ka, va, la = M.append_step(CFG, P, pad_a, ql, kc, vc, z)
        lg_b, kb, vb, lb = M.append_step(CFG, P, pad_b, ql, kc, vc, z)
        np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b), rtol=1e-5)
        # live cache region identical too
        np.testing.assert_allclose(np.asarray(ka[:, :, :, :10]),
                                   np.asarray(kb[:, :, :, :10]), rtol=1e-5)

    def test_pad_garbage_never_observable(self):
        # Decode after a padded append must match decode after exact append.
        t = toks(3, 1, 6)
        kc, vc = full(1)
        z = jnp.zeros(1, jnp.int32)
        # exact: S=6 (supported arbitrary in python; buckets only matter AOT)
        _, k1, v1, l1 = M.append_step(CFG, P, t, jnp.array([6]), kc, vc, z)
        # padded: S=16 bucket
        tp = jnp.concatenate([t, jnp.full((1, 10), 13, jnp.int32)], axis=1)
        _, k2, v2, l2 = M.append_step(CFG, P, tp, jnp.array([6]), kc, vc, z)
        nxt = jnp.array([[5]], jnp.int32)
        lg1, *_ = M.append_step(CFG, P, nxt, jnp.array([1]), k1, v1, l1)
        lg2, *_ = M.append_step(CFG, P, nxt, jnp.array([1]), k2, v2, l2)
        np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), rtol=1e-4, atol=1e-5)

    def test_batch_elements_independent(self):
        # Element 0's result must not depend on element 1's content.
        ta = toks(4, 2, 8)
        tb = ta.at[1].set(toks(5, 1, 8)[0])
        kc, vc = full(2)
        z = jnp.zeros(2, jnp.int32)
        ql = jnp.array([8, 8])
        lg_a, *_ = M.append_step(CFG, P, ta, ql, kc, vc, z)
        lg_b, *_ = M.append_step(CFG, P, tb, ql, kc, vc, z)
        np.testing.assert_allclose(np.asarray(lg_a[0]), np.asarray(lg_b[0]), rtol=1e-5)

    def test_per_element_cache_len(self):
        # Mixed cache lengths in one batch: each element must behave as if
        # it were alone in a batch of 1.
        t8 = toks(6, 1, 8)
        kc1, vc1 = full(1)
        z1 = jnp.zeros(1, jnp.int32)
        _, k_pre, v_pre, l_pre = M.append_step(CFG, P, t8, jnp.array([8]), kc1, vc1, z1)
        q = toks(7, 1, 4)
        lg_solo, *_ = M.append_step(CFG, P, q, jnp.array([4]), k_pre, v_pre, l_pre)
        # batch of 2: element 0 has 8-token history, element 1 empty
        kc2 = jnp.concatenate([k_pre, kc1], axis=1)
        vc2 = jnp.concatenate([v_pre, vc1], axis=1)
        q2 = jnp.concatenate([q, toks(8, 1, 4)], axis=0)
        lg_b, *_ = M.append_step(CFG, P, q2, jnp.array([4, 4]),
                                 kc2, vc2, jnp.array([8, 0], jnp.int32))
        np.testing.assert_allclose(np.asarray(lg_solo[0]), np.asarray(lg_b[0]),
                                   rtol=1e-4, atol=1e-5)


class TestMatKVEquivalence:
    """The paper's §III-B accuracy question, reduced to its exact core."""

    def test_single_doc_matkv_equals_vanilla(self):
        # One retrieved doc: MatKV (precompute doc KV, reload, append query)
        # must be numerically identical to Vanilla (doc+query in one pass).
        doc = toks(10, 1, 24)
        query = toks(11, 1, 8)
        kc, vc = full(1)
        z = jnp.zeros(1, jnp.int32)
        # Vanilla
        _, kv_k, kv_v, l = M.append_step(CFG, P, doc, jnp.array([24]), kc, vc, z)
        lg_v, *_ = M.append_step(CFG, P, query, jnp.array([8]), kv_k, kv_v, l)
        # MatKV: "materialize" = extract first 24 slots, reload into fresh cache
        mat_k = np.asarray(kv_k[:, :, :, :24])
        mat_v = np.asarray(kv_v[:, :, :, :24])
        kc2, vc2 = full(1)
        kc2 = kc2.at[:, :, :, :24].set(mat_k)
        vc2 = vc2.at[:, :, :, :24].set(mat_v)
        lg_m, *_ = M.append_step(CFG, P, query, jnp.array([8]), kc2, vc2,
                                 jnp.array([24], jnp.int32))
        np.testing.assert_allclose(np.asarray(lg_v), np.asarray(lg_m), rtol=1e-4, atol=1e-5)

    def test_two_doc_matkv_differs_only_by_cross_attention(self):
        # Two docs: MatKV concatenates independently-prefilled KVs (positions
        # restart per doc, no cross-doc attention). Outputs are close but not
        # identical to Vanilla — this is the Table VI fidelity gap.
        d1, d2 = toks(12, 1, 16), toks(13, 1, 16)
        query = toks(14, 1, 8)
        kc, vc = full(1)
        z = jnp.zeros(1, jnp.int32)
        # Vanilla: d1 + d2 + q sequential
        _, k, v, l = M.append_step(CFG, P, d1, jnp.array([16]), kc, vc, z)
        _, k, v, l = M.append_step(CFG, P, d2, jnp.array([16]), k, v, l)
        lg_v, *_ = M.append_step(CFG, P, query, jnp.array([8]), k, v, l)
        # MatKV: independent prefills, concatenated caches
        _, k1, v1, _ = M.append_step(CFG, P, d1, jnp.array([16]), kc, vc, z)
        _, k2, v2, _ = M.append_step(CFG, P, d2, jnp.array([16]), kc, vc, z)
        kc2, vc2 = full(1)
        kc2 = kc2.at[:, :, :, :16].set(k1[:, :, :, :16]).at[:, :, :, 16:32].set(k2[:, :, :, :16])
        vc2 = vc2.at[:, :, :, :16].set(v1[:, :, :, :16]).at[:, :, :, 16:32].set(v2[:, :, :, :16])
        lg_m, *_ = M.append_step(CFG, P, query, jnp.array([8]), kc2, vc2,
                                 jnp.array([32], jnp.int32))
        # not identical (cross-doc attention dropped) ...
        assert not np.allclose(np.asarray(lg_v), np.asarray(lg_m), rtol=1e-4)
        # ... but same argmax would indicate mild perturbation; we only require
        # bounded relative distortion of the logit vector.
        rel = np.linalg.norm(np.asarray(lg_v - lg_m)) / np.linalg.norm(np.asarray(lg_v))
        assert rel < 0.5, rel


class TestGreedyDecode:
    def test_decode_deterministic(self):
        kc, vc = full(1)
        _, k, v, l = M.append_step(CFG, P, toks(20, 1, 16), jnp.array([16]),
                                   kc, vc, jnp.zeros(1, jnp.int32))
        first = jnp.array([3], jnp.int32)
        seq1, *_ = M.greedy_decode(CFG, P, k, v, l, first, 8)
        seq2, *_ = M.greedy_decode(CFG, P, k, v, l, first, 8)
        np.testing.assert_array_equal(np.asarray(seq1), np.asarray(seq2))
        assert seq1.shape == (1, 8)

    def test_decode_extends_cache(self):
        kc, vc = full(1)
        _, k, v, l = M.append_step(CFG, P, toks(21, 1, 8), jnp.array([8]),
                                   kc, vc, jnp.zeros(1, jnp.int32))
        _, k2, v2, l2 = M.greedy_decode(CFG, P, k, v, l, jnp.array([3], jnp.int32), 5)
        assert int(l2[0]) == 8 + 4  # n_steps-1 appends


@settings(max_examples=8, deadline=None)
@given(s1=st.integers(1, 12), s2=st.integers(1, 12), seed=st.integers(0, 50))
def test_incremental_append_associativity(s1, s2, seed):
    """append(a) then append(b) == append(a++b) for any split (hypothesis)."""
    t = toks(seed, 1, s1 + s2)
    kc, vc = M.empty_cache(CFG, 1)
    z = jnp.zeros(1, jnp.int32)
    lg_one, k1, v1, _ = M.append_step(CFG, P, t, jnp.array([s1 + s2]), kc, vc, z)
    _, ka, va, la = M.append_step(CFG, P, t[:, :s1], jnp.array([s1]), kc, vc, z)
    lg_two, kb, vb, _ = M.append_step(CFG, P, t[:, s1:], jnp.array([s2]), ka, va, la)
    np.testing.assert_allclose(np.asarray(lg_one), np.asarray(lg_two), rtol=2e-4, atol=2e-5)


def test_aot_configs_param_counts():
    # guard against accidental config drift (the manifest is a cross-language ABI)
    assert CONFIGS["tiny"].param_count() < CONFIGS["small"].param_count() < CONFIGS["base"].param_count()
    for c in CONFIGS.values():
        assert c.max_ctx % 256 == 0
        assert c.n_heads % c.n_kv_heads == 0


class TestPackedState:
    """The packed flat-state entry (what aot.py actually lowers) must agree
    with the structured append_step it wraps."""

    def test_packed_matches_structured(self):
        import numpy as np
        b, s, c = 2, 8, CFG.max_ctx
        fn, specs = M.make_packed_step(CFG, b, s, c)
        logits_n, cache_n, total = M.state_layout(CFG, b, c)
        assert specs[-1].shape == (total,)
        t = toks(30, b, s)
        ql = jnp.array([8, 5], jnp.int32)
        cl = jnp.zeros(b, jnp.int32)
        kc, vc = full(b)
        state = jnp.concatenate([jnp.zeros(logits_n), kc.reshape(-1), vc.reshape(-1)])
        weights = [getattr(P, n) for n in M.PARAM_ORDER]
        out = fn(*weights, t, ql, cl, state)
        lg, k2, v2, _ = M.append_step(CFG, P, t, ql, kc, vc, cl)
        np.testing.assert_allclose(np.asarray(out[:logits_n]).reshape(b, CFG.vocab),
                                   np.asarray(lg), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(out[logits_n:logits_n + cache_n]),
                                   np.asarray(k2).reshape(-1), rtol=1e-5, atol=1e-6)

    def test_packed_roundtrip_two_steps(self):
        """Feeding the packed output back as state must equal structured chaining."""
        import numpy as np
        b, s, c = 1, 4, CFG.max_ctx
        fn, _ = M.make_packed_step(CFG, b, s, c)
        logits_n, cache_n, total = M.state_layout(CFG, b, c)
        weights = [getattr(P, n) for n in M.PARAM_ORDER]
        kc, vc = full(b)
        state = jnp.concatenate([jnp.zeros(logits_n), kc.reshape(-1), vc.reshape(-1)])
        t1, t2 = toks(31, b, 4), toks(32, b, 4)
        ql = jnp.array([4], jnp.int32)
        s1 = fn(*weights, t1, ql, jnp.zeros(b, jnp.int32), state)
        s2 = fn(*weights, t2, ql, jnp.array([4], jnp.int32), s1)
        # structured chain
        _, k, v, l = M.append_step(CFG, P, t1, ql, kc, vc, jnp.zeros(b, jnp.int32))
        lg, *_ = M.append_step(CFG, P, t2, ql, k, v, l)
        np.testing.assert_allclose(np.asarray(s2[:logits_n]).reshape(b, CFG.vocab),
                                   np.asarray(lg), rtol=1e-5, atol=1e-6)
