"""Model configurations and shape buckets shared by L1/L2/aot and (via
manifest.json) the rust coordinator.

Three LLaMA-architecture configs stand in for the paper's LLaMA 3.2 3B /
3.1 8B / 3.1 70B (see DESIGN.md "Substitutions"): every systems quantity
the paper measures (prefill FLOPs vs KV-cache bytes, load-vs-compute
crossover) is architecture-intrinsic, so scaled-down configs with seeded
weights preserve the shapes of all figures.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    max_ctx: int  # C: padded KV-cache length (static for HLO)
    rope_theta: float = 10000.0

    @property
    def kv_bytes_per_token(self) -> int:
        """f32 KV-cache bytes contributed by one token (all layers)."""
        return self.n_layers * 2 * self.n_kv_heads * self.head_dim * 4

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        qkvo = d * self.n_heads * self.head_dim * 2 + d * self.n_kv_heads * self.head_dim * 2
        mlp = 3 * d * f
        per_layer = qkvo + mlp + 2 * d
        return v * d + self.n_layers * per_layer + d + d * v


# Paper-role mapping: tiny ~ "3B-class", small ~ "8B-class", base ~ "70B-class".
# max_ctx = 2304 covers 2x1024-token chunks + 32-token query bucket + 100
# decode tokens + headroom, and is a multiple of the 256-token chunk bucket.
CONFIGS = {
    "tiny": ModelConfig("tiny", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                        head_dim=32, d_ff=352, vocab=512, max_ctx=2304),
    "small": ModelConfig("small", n_layers=6, d_model=256, n_heads=8, n_kv_heads=2,
                         head_dim=32, d_ff=704, vocab=1024, max_ctx=2304),
    "base": ModelConfig("base", n_layers=8, d_model=512, n_heads=8, n_kv_heads=2,
                        head_dim=64, d_ff=1408, vocab=2048, max_ctx=2304),
}

# Static shape buckets lowered to HLO: S = tokens appended per call
# (1 = decode step, 32 = query sub-prefill, 256 = chunked document prefill),
# B = batch-size buckets used by the dynamic batcher.
S_BUCKETS = (1, 32, 256)
B_BUCKETS = (1, 2, 4, 8)
CHUNK_TOKENS = 256          # materialization granularity (doc = N chunks)
QUERY_BUCKET = 32


def config_dict(cfg: ModelConfig) -> dict:
    d = asdict(cfg)
    d["kv_bytes_per_token"] = cfg.kv_bytes_per_token
    d["param_count"] = cfg.param_count()
    return d
