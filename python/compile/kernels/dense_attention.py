"""L1 Pallas kernel: batch-grid masked attention over the padded cache.

A second attention kernel with the *opposite* blocking strategy from
`attention.flash_attention`:

* `flash_attention` — canonical TPU flash attention: grid
  (B, H, nQ, nK), K streamed through VMEM in blocks, online softmax in
  scratch. Best VMEM locality, but interpret mode (the only way to run
  Pallas on the CPU PJRT plugin) pays ~2 ms of interpreter overhead per
  grid step — 576 steps/layer at serve shapes.

* `dense_attention` (this kernel) — grid (B,): one grid step per batch
  element, all heads and the whole padded cache resident as the block,
  plain masked softmax in the body. For decode/sub-prefill shapes the
  per-element KV block is Hkv*C*D*4 ≈ 1.2 MB — comfortably VMEM-resident
  on a real TPU too, making this a legitimate decode-attention design
  (batch-parallel, cache-in-VMEM), not just an interpreter workaround.

Both kernels are verified against the same oracle (`ref.py`); aot.py
selects per entry point (dense by default — see DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _dense_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, *, group):
    q = q_ref[0]  # [H, S, D]
    k = k_ref[0]  # [Hkv, C, D]
    v = v_ref[0]  # [Hkv, C, D]
    h, s_len, d = q.shape
    h_kv, c_len, _ = k.shape
    scale = 1.0 / (d ** 0.5)

    # GQA without materializing repeated KV: fold groups into the head dim
    # of a 3D dot_general batched over kv heads.
    qg = q.reshape(h_kv, group * s_len, d)
    scores = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # [Hkv, group*S, C]
    scores = scores.reshape(h, s_len, c_len) * scale

    rows = jax.lax.broadcasted_iota(jnp.int32, (s_len, c_len), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (s_len, c_len), 1)
    valid = cols <= off_ref[0] + rows
    scores = jnp.where(valid[None], scores, NEG_INF)

    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / p.sum(axis=-1, keepdims=True)

    pg = p.reshape(h_kv, group * s_len, c_len)
    out = jax.lax.dot_general(
        pg, v, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # [Hkv, group*S, D]
    o_ref[0] = out.reshape(h, s_len, d)


@jax.jit
def dense_attention(q, k, v, off):
    """Same contract as `flash_attention`: q [B,H,S,D], k/v [B,Hkv,C,D],
    off [B]; row i attends cache slot j iff j <= off[b] + i."""
    b, h, s_len, d = q.shape
    _, h_kv, c_len, _ = k.shape
    assert h % h_kv == 0
    group = h // h_kv
    kernel = functools.partial(_dense_kernel, group=group)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, h, s_len, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h_kv, c_len, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h_kv, c_len, d), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, s_len, d), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_len, d), jnp.float32),
        interpret=True,
    )(off.astype(jnp.int32), q, k, v)


def vmem_footprint(h: int, h_kv: int, s_len: int, c_len: int, d: int) -> int:
    """VMEM bytes per grid step on a real TPU (perf-model input)."""
    f32 = 4
    return f32 * (h * s_len * d * 2 + 2 * h_kv * c_len * d + h * s_len * c_len)
