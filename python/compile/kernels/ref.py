"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: `python/tests/test_kernel.py`
sweeps shapes with hypothesis and asserts the Pallas kernels match these
references via `assert_allclose`.
"""

import jax.numpy as jnp


def flash_attention_ref(q, k, v, off):
    """Reference masked attention over a padded KV cache.

    Same contract as kernels.attention.flash_attention:
      q [B,H,S,D], k/v [B,Hkv,C,D], off [B] — row i sees slot j iff
      j <= off[b] + i.
    """
    _, h, s_len, d = q.shape
    _, h_kv, c_len, _ = k.shape
    group = h // h_kv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bhsd,bhcd->bhsc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    rows = jnp.arange(s_len)[:, None]
    cols = jnp.arange(c_len)[None, :]
    valid = cols[None, None] <= off.astype(jnp.int32)[:, None, None, None] + rows[None, None]
    s = jnp.where(valid, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhsc,bhcd->bhsd", p, v.astype(jnp.float32))


def rmsnorm_ref(x, w, eps=1e-5):
    """Reference RMSNorm over the last axis: x * rsqrt(mean(x^2)+eps) * w."""
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return x32 * (1.0 / jnp.sqrt(ms + eps)) * w.astype(jnp.float32)
