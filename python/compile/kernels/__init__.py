"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .attention import flash_attention, vmem_footprint  # noqa: F401
from .rmsnorm import rmsnorm  # noqa: F401
