"""L1 Pallas kernel: fused RMSNorm.

Hot on the decode path (two invocations per layer per token).  Each grid
step normalizes a block of rows entirely in VMEM: one read of the row, one
write, no intermediate mean/variance round-trip through HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * w_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = DEFAULT_BLOCK_ROWS):
    """RMSNorm over the last axis of a [N, d] (or reshapeable) array."""
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    block_rows = min(block_rows, n)
    # Pad rows to a block multiple; padded rows normalize garbage, dropped.
    pad = (-n) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        interpret=True,
    )(x2, w)
    if pad:
        out = out[:n]
    return out.reshape(orig_shape)
