"""L1 Pallas kernel: fused flash attention over a padded KV cache.

This is the paper's compute hot-spot (the prefill / sub-prefill / decode
attention) restated for the TPU memory hierarchy (DESIGN.md
"Hardware-Adaptation"):

- grid = (B, H, nQ, nK); the K dimension iterates minor-most so the online
  softmax state (acc, m, l) carries across K blocks in VMEM scratch —
  the HBM<->VMEM analogue of FlashAttention's SRAM loop.  The full
  [S, C] score matrix never materializes.
- QK^T and PV contractions run through ``dot_general`` with
  ``preferred_element_type=f32`` (MXU systolic array on real TPU).
- GQA is expressed in the K/V BlockSpec index map (``h // group``), so
  grouped KV heads are *never* expanded in memory.
- One mask rule serves all three entry points (chunked prefill, query
  sub-prefill over loaded MatKV caches, single-token decode): query row
  ``i`` written at cache slot ``off[b] + i`` may attend cache slot ``j``
  iff ``j <= off[b] + i``.  Cache slots beyond the current length hold
  garbage from bucket padding and are excluded by the same rule.

Executed with ``interpret=True`` everywhere in this repo: the CPU PJRT
plugin cannot run Mosaic custom-calls.  Real-TPU perf is estimated from
the VMEM footprint / MXU utilization of the block shapes (EXPERIMENTS.md
section "Perf").
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# Default block shapes: 128 rows keeps the QK^T tile MXU-shaped on the
# sublane axis; 256 K columns amortizes softmax state updates while the
# K/V tiles (256 x head_dim) stay well under VMEM (see vmem_footprint).
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 256


def _attn_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                 *, block_q, block_k, n_k, scale):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]  # [BQ, D]
    k = k_ref[0, 0]  # [BK, D]
    v = v_ref[0, 0]  # [BK, D]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    valid = cols <= off_ref[0] + rows
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    # Explicitly re-mask p: for rows whose every column in this K block is
    # invalid, exp(NEG_INF - NEG_INF) would otherwise contribute 1.
    p = jnp.where(valid, jnp.exp(s - m_cur[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ik == n_k - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def flash_attention(q, k, v, off, *, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K):
    """Masked flash attention against a padded KV cache.

    Args:
      q:   [B, H, S, D]  query heads (RoPE already applied).
      k:   [B, Hkv, C, D] padded key cache (slots >= length are garbage).
      v:   [B, Hkv, C, D] padded value cache.
      off: [B] int32 — per-element cache length *before* this call's tokens
           were written; row i attends slots j <= off[b] + i.

    Returns: [B, H, S, D] attention output, f32.
    """
    b, h, s_len, d = q.shape
    _, h_kv, c_len, _ = k.shape
    assert h % h_kv == 0, (h, h_kv)
    group = h // h_kv
    block_q = min(block_q, s_len)
    block_k = min(block_k, c_len)
    assert s_len % block_q == 0 and c_len % block_k == 0, (s_len, c_len, block_q, block_k)
    n_q, n_k = s_len // block_q, c_len // block_k
    scale = 1.0 / (d ** 0.5)

    grid = (b, h, n_q, n_k)
    kernel = functools.partial(_attn_kernel, block_q=block_q, block_k=block_k,
                               n_k=n_k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_, iq, ik: (b_,)),
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_len, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),  # acc
            pltpu.VMEM((block_q,), jnp.float32),    # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),    # l (running denom)
        ],
        interpret=True,
    )(off.astype(jnp.int32), q, k, v)


def vmem_footprint(block_q: int, block_k: int, d: int) -> int:
    """Bytes of VMEM resident per grid step (perf-model input, not runtime)."""
    f32 = 4
    tiles = (block_q * d      # q
             + 2 * block_k * d  # k, v
             + block_q * d      # o / acc
             + 2 * block_q      # m, l
             + block_q * block_k)  # scores
    return tiles * f32
