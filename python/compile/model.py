"""L2: LLaMA-architecture decoder (RMSNorm / RoPE / GQA / SwiGLU) in JAX.

One parametric entry point — ``append_step`` — serves every phase of the
MatKV serving stack (DESIGN.md "Model configs"):

  * S=256, empty cache      → chunked document prefill (ingest/materialize,
                              and the Vanilla full-recompute baseline);
  * S=32, preloaded cache   → query sub-prefill over KV caches loaded from
                              flash (the MatKV serve path);
  * S=1                     → one autoregressive decode step.

The KV cache is a padded [L, B, Hkv, C, D] pair of arrays threaded
functionally through the call; new tokens are written at per-batch-element
offsets ``cache_len[b]`` with dynamic_update_slice, and the L1 Pallas
attention kernel masks slots ``j > cache_len[b] + i``.  Static shapes
(S/B/C buckets) keep the lowered HLO fully AOT-compilable; the rust
coordinator picks the bucket per batch.

Build-time only: this module is lowered once by aot.py and never imported
at serving time.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.attention import flash_attention
from .kernels.dense_attention import dense_attention
from .kernels.rmsnorm import rmsnorm

# Attention kernel used by append_step. Both are Pallas kernels verified
# against kernels/ref.py; `dense` (grid over batch, cache-in-VMEM) is the
# serving default — under interpret=True it costs one interpreter step per
# batch element instead of one per (b, h, q-block, k-block), a ~30x
# wall-clock difference on the CPU PJRT backend. `flash` is the
# canonically-blocked TPU variant kept for compile-only targets and
# ablation (aot.py --kernel flash). See DESIGN.md "Perf".
ATTENTION_KERNELS = {"dense": dense_attention, "flash": flash_attention}
_attn_impl = dense_attention


def set_attention_kernel(name: str) -> None:
    """Select the attention kernel lowered into subsequent tracings."""
    global _attn_impl
    _attn_impl = ATTENTION_KERNELS[name]

# Flat parameter order — the ABI between aot.py-exported weight blobs and
# the rust runtime (runtime/weights.rs). Do not reorder.
PARAM_ORDER = (
    "tok_emb",   # [V, d]
    "wq",        # [L, d, H*D]
    "wk",        # [L, d, Hkv*D]
    "wv",        # [L, d, Hkv*D]
    "wo",        # [L, H*D, d]
    "w_gate",    # [L, d, f]
    "w_up",      # [L, d, f]
    "w_down",    # [L, f, d]
    "ln_attn",   # [L, d]
    "ln_mlp",    # [L, d]
    "ln_final",  # [d]
    "lm_head",   # [d, V]
)


class Params(NamedTuple):
    tok_emb: jax.Array
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    w_gate: jax.Array
    w_up: jax.Array
    w_down: jax.Array
    ln_attn: jax.Array
    ln_mlp: jax.Array
    ln_final: jax.Array
    lm_head: jax.Array


def param_shapes(cfg: ModelConfig) -> dict:
    L, d, f, v = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "tok_emb": (v, d),
        "wq": (L, d, hq * hd),
        "wk": (L, d, hkv * hd),
        "wv": (L, d, hkv * hd),
        "wo": (L, hq * hd, d),
        "w_gate": (L, d, f),
        "w_up": (L, d, f),
        "w_down": (L, f, d),
        "ln_attn": (L, d),
        "ln_mlp": (L, d),
        "ln_final": (d,),
        "lm_head": (d, v),
    }


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Deterministic seeded init (stands in for pretrained weights; see
    DESIGN.md Substitutions — all measured quantities are weight-agnostic)."""
    shapes = param_shapes(cfg)
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(PARAM_ORDER))
    out = {}
    resid_scale = 1.0 / (2.0 * cfg.n_layers) ** 0.5
    for k, name in zip(keys, PARAM_ORDER):
        shape = shapes[name]
        if name.startswith("ln"):
            out[name] = jnp.ones(shape, jnp.float32)
        else:
            w = jax.random.normal(k, shape, jnp.float32) * 0.02
            if name in ("wo", "w_down"):
                w = w * resid_scale
            out[name] = w
    return Params(**out)


def _rope(x, pos, theta: float):
    """Rotate-half RoPE. x [B,Hx,S,D], pos [B,S] int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)  # [half]
    ang = pos.astype(jnp.float32)[:, None, :, None] * freq  # [B,1,S,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _write_cache(cache_l, new, cache_len):
    """Per-batch-element dynamic_update_slice.

    cache_l [B,Hkv,C,D], new [B,Hkv,S,D], cache_len [B] → updated cache.
    Pad rows (i >= qlen) write garbage past the live region; the attention
    mask guarantees those slots are never read before being overwritten.
    """
    def upd(c, n, start):
        return jax.lax.dynamic_update_slice(c, n, (0, start, 0))
    return jax.vmap(upd)(cache_l, new, cache_len)


def append_step(cfg: ModelConfig, params: Params, tokens, qlen,
                kcache, vcache, cache_len):
    """Append S tokens to the cache and return last-live-token logits.

    Args:
      tokens:    [B, S] int32 (padded with arbitrary ids beyond qlen).
      qlen:      [B] int32 — live tokens per element, 1 <= qlen <= S.
      kcache:    [L, B, Hkv, C, D] f32 padded key cache.
      vcache:    [L, B, Hkv, C, D] f32 padded value cache.
      cache_len: [B] int32 — live cache length before this call.

    Returns: (logits [B, V] f32 of token qlen-1, new_kcache, new_vcache,
              new_len [B]).
    """
    b, s = tokens.shape
    x = params.tok_emb[tokens]  # [B,S,d]
    pos = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # [B,S]

    layer_params = (params.wq, params.wk, params.wv, params.wo,
                    params.w_gate, params.w_up, params.w_down,
                    params.ln_attn, params.ln_mlp)

    def layer(x, scanned):
        (wq, wk, wv, wo, w_gate, w_up, w_down, ln_attn, ln_mlp,
         kc_l, vc_l) = scanned
        h = rmsnorm(x, ln_attn)
        q = (h @ wq).reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        k = (h @ wk).reshape(b, s, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        v = (h @ wv).reshape(b, s, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        q = _rope(q, pos, cfg.rope_theta)
        k = _rope(k, pos, cfg.rope_theta)
        kc_l = _write_cache(kc_l, k, cache_len)
        vc_l = _write_cache(vc_l, v, cache_len)
        attn = _attn_impl(q, kc_l, vc_l, cache_len)  # [B,H,S,D] f32
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
        x = x + attn @ wo
        h2 = rmsnorm(x, ln_mlp)
        x = x + (jax.nn.silu(h2 @ w_gate) * (h2 @ w_up)) @ w_down
        return x, (kc_l, vc_l)

    x, (new_k, new_v) = jax.lax.scan(layer, x, layer_params + (kcache, vcache))
    xf = rmsnorm(x, params.ln_final)
    idx = (qlen - 1).astype(jnp.int32)[:, None, None]
    last = jnp.take_along_axis(xf, idx, axis=1)[:, 0]  # [B,d]
    logits = last @ params.lm_head
    return logits, new_k, new_v, cache_len + qlen


def state_layout(cfg: ModelConfig, batch: int, max_ctx: int):
    """Packed-state layout: [logits (B*V) ; kcache ; vcache], flat f32.

    PJRT (via the xla crate) returns multi-output computations as a single
    *tuple* device buffer with no tuple-element extraction API, which would
    force a full host round-trip of the KV cache on every decode step.
    Packing (logits, kcache, vcache) into ONE flat f32 array instead makes
    the output a plain array buffer that rust feeds straight back into the
    next execute_b call — the decode loop stays device-resident and only
    the logits prefix (B*V f32, at offset 0 by construction) is copied to
    host each step for sampling.
    """
    logits_n = batch * cfg.vocab
    cache_n = cfg.n_layers * batch * cfg.n_kv_heads * max_ctx * cfg.head_dim
    return logits_n, cache_n, logits_n + 2 * cache_n


def make_packed_step(cfg: ModelConfig, batch: int, s_bucket: int, max_ctx: int):
    """Flat-argument packed-state entry point for AOT lowering.

    Signature: fn(*weights_in_PARAM_ORDER, tokens [B,S] i32, qlen [B] i32,
    cache_len [B] i32, state f32[N]) -> state' f32[N]; all shapes static per
    (batch, s_bucket, max_ctx). The logits region of the *input* state is
    ignored; cache_len is tracked host-side.
    """
    logits_n, cache_n, total = state_layout(cfg, batch, max_ctx)
    cache_shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_ctx, cfg.head_dim)

    def fn(*args):
        params = Params(*args[:len(PARAM_ORDER)])
        tokens, qlen, cache_len, state = args[len(PARAM_ORDER):]
        kcache = jax.lax.dynamic_slice_in_dim(state, logits_n, cache_n).reshape(cache_shape)
        vcache = jax.lax.dynamic_slice_in_dim(state, logits_n + cache_n, cache_n).reshape(cache_shape)
        logits, new_k, new_v, _ = append_step(cfg, params, tokens, qlen,
                                              kcache, vcache, cache_len)
        return jnp.concatenate([logits.reshape(-1), new_k.reshape(-1),
                                new_v.reshape(-1)])

    shapes = param_shapes(cfg)
    specs = [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in PARAM_ORDER]
    specs += [
        jax.ShapeDtypeStruct((batch, s_bucket), jnp.int32),  # tokens
        jax.ShapeDtypeStruct((batch,), jnp.int32),           # qlen
        jax.ShapeDtypeStruct((batch,), jnp.int32),           # cache_len
        jax.ShapeDtypeStruct((total,), jnp.float32),         # packed state
    ]
    return fn, specs


# ---------------------------------------------------------------------------
# Pure-python reference driver (used by python/tests to validate the
# serving recipes end-to-end before they are re-implemented in rust).
# ---------------------------------------------------------------------------

def empty_cache(cfg: ModelConfig, batch: int, max_ctx=None):
    c = max_ctx or cfg.max_ctx
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, c, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def greedy_decode(cfg, params, kcache, vcache, cache_len, first_token, n_steps):
    """Teacher-free greedy decode loop (reference for the rust loop)."""
    b = first_token.shape[0]
    tok = first_token.reshape(b, 1).astype(jnp.int32)
    out = [tok[:, 0]]
    qlen = jnp.ones((b,), jnp.int32)
    for _ in range(n_steps - 1):
        logits, kcache, vcache, cache_len = append_step(
            cfg, params, tok, qlen, kcache, vcache, cache_len)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32).reshape(b, 1)
        out.append(tok[:, 0])
    return jnp.stack(out, axis=1), kcache, vcache, cache_len
