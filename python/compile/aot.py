"""AOT driver: lower L2 entry points to HLO text + export weights.

Emits, per model config:

  artifacts/<config>/append_s{S}_b{B}[_c{C}].hlo.txt   — HLO **text** (the
      image's xla_extension 0.5.1 rejects jax>=0.5 serialized protos whose
      instruction ids exceed INT_MAX; the text parser reassigns ids — see
      /opt/xla-example/README.md)
  artifacts/<config>/weights/<name>.bin                — raw little-endian
      f32 blobs in model.PARAM_ORDER
  artifacts/manifest.json                              — the ABI consumed by
      rust/src/runtime: configs, buckets, artifact + weight inventories.

Run once via `make artifacts`; python never appears on the serving path.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, S_BUCKETS, B_BUCKETS, CHUNK_TOKENS, QUERY_BUCKET, config_dict
from . import model as M

INGEST_CTX = 1024  # compact-cache variant for document materialization


def to_hlo_text(lowered) -> str:
    # return_tuple=False: every entry returns ONE flat f32 array (the packed
    # state — see model.state_layout), so the PJRT output is a plain array
    # buffer that rust can feed back via execute_b without any tuple
    # unpacking or host round-trip.
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    return comp.as_hlo_text()


def lower_entry(cfg, batch: int, s_bucket: int, max_ctx: int,
                donate: bool = True) -> str:
    fn, specs = M.make_packed_step(cfg, batch, s_bucket, max_ctx)
    # Donate the packed state: the HLO carries input_output_alias for the
    # state parameter, letting PJRT update the KV cache in place instead
    # of copying ~(2*L*B*Hkv*C*D*4) bytes per step (see DESIGN.md Perf).
    donate_args = (len(M.PARAM_ORDER) + 3,) if donate else ()
    lowered = jax.jit(fn, donate_argnums=donate_args).lower(*specs)
    return to_hlo_text(lowered)


def export_weights(cfg, out_dir: str, seed: int) -> list:
    params = M.init_params(cfg, seed=seed)
    os.makedirs(out_dir, exist_ok=True)
    inventory = []
    for name in M.PARAM_ORDER:
        arr = getattr(params, name)
        path = os.path.join(out_dir, f"{name}.bin")
        data = bytes(jnp.asarray(arr, jnp.float32).tobytes())
        with open(path, "wb") as f:
            f.write(data)
        inventory.append({
            "name": name,
            "file": f"weights/{name}.bin",
            "shape": list(arr.shape),
            "dtype": "f32",
            "sha256": hashlib.sha256(data).hexdigest()[:16],
        })
    return inventory


def golden_case(cfg, seed: int) -> dict:
    """Golden cross-language test vector: run the (s=32, b=1, serve-C)
    packed entry in python on deterministic inputs and record the logits
    prefix. rust/tests/runtime_golden.rs replays it through the PJRT
    artifact and asserts allclose — the end-to-end numerics handshake
    between the python compile path and the rust serve path."""
    s, b, c = 32, 1, cfg.max_ctx
    fn, _ = M.make_packed_step(cfg, b, s, c)
    params = M.init_params(cfg, seed=seed)
    weights = [getattr(params, n) for n in M.PARAM_ORDER]
    tokens = (np.arange(s, dtype=np.int32)[None, :] * 7 + 3) % cfg.vocab
    qlen = np.array([17], np.int32)
    cache_len = np.array([0], np.int32)
    logits_n, _, total = M.state_layout(cfg, b, c)
    state = np.zeros(total, np.float32)
    out = np.asarray(fn(*weights, jnp.asarray(tokens), jnp.asarray(qlen),
                        jnp.asarray(cache_len), jnp.asarray(state)))
    # second step: feed state back, decode one token (s=1 path exercised
    # in rust against its own artifact; golden covers the s=32 feedback)
    return {
        "s": s, "b": b, "c": c,
        "tokens": tokens[0].tolist(),
        "qlen": 17,
        "logits_head": out[:16].astype(float).tolist(),
        "state_l2": float(np.linalg.norm(out[logits_n:logits_n + 4096])),
        "argmax": int(np.argmax(out[:logits_n])),
    }


def entries_for(cfg):
    """(s, b, c) triples lowered for one config."""
    out = []
    for s in S_BUCKETS:
        for b in B_BUCKETS:
            out.append((s, b, cfg.max_ctx))
    for b in B_BUCKETS:  # compact ingest variant: chunk prefill, C=1024
        out.append((CHUNK_TOKENS, b, INGEST_CTX))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--configs", default="tiny,small,base")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kernel", choices=("dense", "flash"), default="dense",
                    help="attention kernel lowered into the artifacts")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable state-buffer donation (ablation)")
    args = ap.parse_args()
    M.set_attention_kernel(args.kernel)

    os.makedirs(args.out, exist_ok=True)
    manifest = {
        "version": 1,
        "attention_kernel": args.kernel,
        "chunk_tokens": CHUNK_TOKENS,
        "query_bucket": QUERY_BUCKET,
        "param_order": list(M.PARAM_ORDER),
        "configs": {},
    }

    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        cdir = os.path.join(args.out, name)
        os.makedirs(cdir, exist_ok=True)
        weights = export_weights(cfg, os.path.join(cdir, "weights"), args.seed)
        artifacts = []
        for (s, b, c) in entries_for(cfg):
            suffix = "" if c == cfg.max_ctx else f"_c{c}"
            fname = f"step_s{s}_b{b}{suffix}.hlo.txt"
            path = os.path.join(cdir, fname)
            if args.force or not os.path.exists(path):
                text = lower_entry(cfg, b, s, c, donate=not args.no_donate)
                with open(path, "w") as f:
                    f.write(text)
                print(f"[aot] {name}/{fname}: {len(text)/1e6:.2f} MB")
            logits_n, cache_n, total = M.state_layout(cfg, b, c)
            artifacts.append({"file": f"{name}/{fname}", "s": s, "b": b, "c": c,
                              "logits_n": logits_n, "cache_n": cache_n,
                              "state_n": total})
        entry = config_dict(cfg)
        entry["weights"] = weights
        entry["artifacts"] = artifacts
        entry["ingest_ctx"] = INGEST_CTX
        golden_path = os.path.join(cdir, "golden.json")
        if args.force or not os.path.exists(golden_path):
            with open(golden_path, "w") as f:
                json.dump(golden_case(cfg, args.seed), f, indent=1)
        manifest["configs"][name] = entry

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
